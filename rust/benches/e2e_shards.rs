//! Shard-pool scaling + batched-ingest benches.
//!
//! Series 1 (`shards/ingest_4streams/shardsK`): aggregate ingest
//! throughput of a fixed multi-stream workload (one producer thread per
//! stream) as the shard count grows 1 → 2 → 4 — unchanged from PR 2.
//!
//! Series 2 (`shards/ingest_4streams_batchB/shards2`): the same
//! 2-shard/4-stream topology at ingest batch sizes 1 / 8 / 64. Batch 1
//! pays one rendezvous round-trip (two thread wake-ups), one command
//! allocation and one m-long scalar kernel loop *per point*; batch 64
//! amortizes the round-trip over the batch and computes the batch's
//! kernel rows as one blocked GEMM. The workload uses short unadjusted
//! streams (the paper's Algorithm 1 regime where each rank-one update
//! is cheap), so the per-point overhead is a first-order cost — exactly
//! the regime the batched front-end targets. The acceptance bar is
//! ≥2× aggregate throughput at batch 64 vs batch 1.
//!
//! Series 3 (`shards/ingest_4streams_async/shards2`): fire-and-forget
//! ingest + final sync on the same workload — the reply-less middle
//! ground (round-trip removed, command-per-point kept).
//!
//! Series 5 (`shards/grow_2to4_{before,during,after}/4streams`): the
//! elastic topology isolated — the batched 4-stream workload on 2
//! shards (`before`), with a live 2→4 grow at the half-feed barrier
//! (`during`: ring change + stream migration + redirected handles,
//! all while the feed continues), and on a pool already grown to 4
//! (`after`). The during/before gap prices the migration machinery;
//! after/before shows the steady-state payoff of the wider pool. The
//! series lands in `BENCH_e2e_shards.json` with the rest, so the CI
//! gate covers rebalance throughput from its first baseline onward.
//!
//! Series 4 (`shards/ingest_4streams_batchB_{fusedrot,seqrot}/shards2`):
//! the blocked rank-b eigen-update isolated — the same batched workload
//! with the back-rotation strategy *forced* to fused vs sequential (and
//! the entries pre-sized via `expected_m`/`expected_batch`, so neither
//! side pays allocator noise). The run also asserts, via the pool's
//! workspace-counted `ws_engine_gemms` rollup, that the fused series
//! dispatches strictly fewer back-rotation GEMMs than the sequential
//! one — the amortization the blocked update exists for.
//!
//! Series 6 (`shards/read95_{snapshot,worker}_rR/shards2`): the
//! lock-free read path under a read-heavy serving mix — ~95:5
//! read:write, one writer batching points in throughout, R ∈ {1,2,4,8}
//! reader threads splitting a fixed projection budget. The `snapshot`
//! side reads through the epoch-published [`ProjectionSnapshot`] with a
//! per-reader `ProjectScratch` (no shard command, no lock in steady
//! state); the `worker` side issues the rendezvous `project` RPC and
//! queues behind the writes. The run also asserts the acceptance
//! signature outside the timed region: the snapshot series finishes
//! with `worker_reads == 0` while `snapshot_reads` carries the whole
//! budget, and multi-reader snapshot medians must not degrade against
//! the single-reader baseline (the scaling itself lands in the JSON
//! trajectory — core counts vary too much across CI hosts to hard-gate
//! a speedup).
//!
//! Series 7 (`shards/bounded_{off,uniform,leverage}/1stream`): the
//! bounded-memory stream isolated — one long batched feed, unbounded
//! (`off`) vs capped at a fixed landmark budget with uniform or
//! leverage-score eviction. The unbounded run's per-point cost grows
//! with `m`; the capped runs hold `m` at the cap, so the series prices
//! what an eviction costs against what a growing eigensystem costs.
//! Outside the timed region the run asserts the bounded signature: `m`
//! pinned at the cap, a positive eviction count, and resident bytes a
//! fraction of the unbounded run's.
//!
//! Series 8 (`shards/tier_{exact,rff,shadow}/mM`): the engine-tier
//! ladder — one stream of M points driven through the paper-exact
//! eigensystem, the fixed-memory RFF + frequent-directions sketch, and
//! the shadow pairing of both, at two stream lengths. The exact
//! engine's per-point cost grows with the retained landmark count m;
//! the sketch's is O(D·r) regardless — the run asserts the sketched
//! per-point median stays flat across the size ladder, which is the
//! tier's acceptance signature. The shadow rows price running both
//! engines side by side, and the run asserts their divergence gauge
//! actually populated.
//!
//! Emits `BENCH_e2e_shards.json` for the perf trajectory and the CI
//! regression gate.

use inkpca::coordinator::{
    EngineConfig, KernelConfig, PoolConfig, PoolSnapshot, ProjectScratch, ShardPool, StreamConfig,
    StreamRouter, StreamTier,
};
use inkpca::data::{load, Dataset};
use inkpca::kpca::{BatchRotation, EvictionPolicy};
use inkpca::util::bench::Bench;

fn scaling_cfg() -> StreamConfig {
    StreamConfig {
        kernel: KernelConfig::Rbf { sigma: 2.0 },
        mean_adjust: true,
        seed_points: 10,
        ..StreamConfig::default()
    }
}

/// Short unadjusted streams: rank-one updates stay cheap, so the
/// per-point ingest overhead (round-trip, allocation, scalar kernel
/// loop) is what the series measures.
fn batch_cfg() -> StreamConfig {
    StreamConfig {
        kernel: KernelConfig::Rbf { sigma: 2.0 },
        mean_adjust: false,
        seed_points: 4,
        ..StreamConfig::default()
    }
}

/// Series-4 config: forced rotation strategy + open-time reserve sized
/// to the workload, so the two sides differ only in how back-rotations
/// are applied.
fn rot_cfg(rot: BatchRotation, n_points: usize, batch: usize) -> StreamConfig {
    StreamConfig {
        batch_rotation: Some(rot),
        expected_m: n_points,
        expected_batch: batch,
        ..batch_cfg()
    }
}

fn spawn_pool(shards: usize) -> (ShardPool, StreamRouter) {
    let pool = ShardPool::spawn(PoolConfig {
        shards,
        queue: 64,
        engine: EngineConfig::Native,
        ..PoolConfig::default()
    });
    let router = pool.router();
    (pool, router)
}

/// Drive `datasets.len()` producer threads, one stream each, shipping
/// points in `batch`-sized `ingest_many` commands (plain `ingest` at
/// batch 1); returns the pool snapshot taken while the streams are
/// still open (accepted totals + workspace gauges).
fn run_batched(
    datasets: &[Dataset],
    cfg: &StreamConfig,
    shards: usize,
    batch: usize,
) -> PoolSnapshot {
    let (pool, router) = spawn_pool(shards);
    std::thread::scope(|scope| {
        for (si, ds) in datasets.iter().enumerate() {
            let r = router.clone();
            let cfg = cfg.clone();
            scope.spawn(move || {
                let id = format!("stream-{si}");
                let h = r.open_stream(&id, ds.dim(), cfg).unwrap();
                if batch <= 1 {
                    // Deliberately the per-point rendezvous verb — the
                    // baseline the batch ladder is measured against.
                    for i in 0..ds.n() {
                        r.ingest(&h, ds.x.row(i).to_vec()).unwrap();
                    }
                } else {
                    r.ingest_all(&h, ds.x.as_slice(), ds.dim(), batch).unwrap();
                }
            });
        }
    });
    let snap = router.pool_snapshot().unwrap();
    pool.shutdown();
    snap
}

/// How the grow series exercises the elastic topology.
#[derive(Clone, Copy, PartialEq, Eq)]
enum GrowMode {
    /// Plain 2-shard run — the pre-grow baseline.
    Before,
    /// 2 shards at open; two `add_shard` calls (ring change + live
    /// stream migration) fire at the half-feed barrier while producers
    /// hold, and the second half flows through the original (now
    /// possibly redirected) handles.
    During,
    /// Grown 2→4 before any stream opens — post-grow steady state.
    After,
}

/// Batched 4-stream workload around a 2→4 shard grow; returns the pool
/// snapshot for the accept/migration assertions.
fn run_grow(
    datasets: &[Dataset],
    cfg: &StreamConfig,
    batch: usize,
    mode: GrowMode,
) -> PoolSnapshot {
    let (pool, router) = spawn_pool(2);
    if mode == GrowMode::After {
        router.add_shard().unwrap();
        router.add_shard().unwrap();
    }
    let barrier = std::sync::Barrier::new(datasets.len() + 1);
    std::thread::scope(|scope| {
        for (si, ds) in datasets.iter().enumerate() {
            let r = router.clone();
            let cfg = cfg.clone();
            let barrier = &barrier;
            scope.spawn(move || {
                let id = format!("stream-{si}");
                let h = r.open_stream(&id, ds.dim(), cfg).unwrap();
                let flat = ds.x.as_slice();
                let half = (ds.n() / 2) * ds.dim();
                r.ingest_all(&h, &flat[..half], ds.dim(), batch).unwrap();
                barrier.wait();
                barrier.wait();
                r.ingest_all(&h, &flat[half..], ds.dim(), batch).unwrap();
            });
        }
        barrier.wait();
        if mode == GrowMode::During {
            router.add_shard().unwrap();
            router.add_shard().unwrap();
        }
        barrier.wait();
    });
    let snap = router.pool_snapshot().unwrap();
    pool.shutdown();
    snap
}

/// Fire-and-forget variant: every point is a reply-less command; one
/// sync per stream at the end drains deferred errors.
fn run_async(datasets: &[Dataset], cfg: &StreamConfig, shards: usize) -> u64 {
    let (pool, router) = spawn_pool(shards);
    std::thread::scope(|scope| {
        for (si, ds) in datasets.iter().enumerate() {
            let r = router.clone();
            let cfg = cfg.clone();
            scope.spawn(move || {
                let id = format!("stream-{si}");
                let h = r.open_stream(&id, ds.dim(), cfg).unwrap();
                for i in 0..ds.n() {
                    r.ingest_async(&h, ds.x.row(i).to_vec()).unwrap();
                }
                assert_eq!(r.sync(&h).unwrap(), 0);
            });
        }
    });
    let snap = router.pool_snapshot().unwrap();
    pool.shutdown();
    snap.accepted
}

/// Series-6 workload: a read-heavy (~95:5) serving mix on one stream.
/// A writer keeps `ingest_many` batches flowing while `readers` threads
/// split a fixed budget of single-point projections — through the
/// epoch-published snapshot (per-reader [`ProjectScratch`], no shard
/// command) or through the worker's rendezvous `project` RPC (queued
/// behind the writes). Returns the pool snapshot so the caller can
/// assert where the reads were served.
fn run_read_heavy(
    ds: &Dataset,
    readers: usize,
    reads: u64,
    write_points: usize,
    snapshot_path: bool,
) -> PoolSnapshot {
    let (pool, router) = spawn_pool(2);
    let dim = ds.dim();
    let h = router.open_stream("serve", dim, batch_cfg()).unwrap();
    // Warm corpus + first publish before the mix starts.
    router.ingest_all(&h, ds.x.as_slice(), dim, 8).unwrap();
    router.sync(&h).unwrap();
    std::thread::scope(|scope| {
        // The 5% side: synthetic points in batches of 8, concurrent
        // with every read below.
        {
            let r = router.clone();
            let h = h.clone();
            scope.spawn(move || {
                let mut batch = Vec::with_capacity(8 * dim);
                for p in 0..write_points {
                    for d in 0..dim {
                        batch.push(((p * dim + d) as f64 * 0.137).sin());
                    }
                    if batch.len() == 8 * dim || p + 1 == write_points {
                        let full = std::mem::replace(&mut batch, Vec::with_capacity(8 * dim));
                        r.ingest_many(&h, full).unwrap();
                    }
                }
            });
        }
        // The 95% side: `readers` threads splitting the `reads` budget.
        for t in 0..readers as u64 {
            let r = router.clone();
            let h = h.clone();
            let probe = ds.x.row(t as usize % ds.n());
            let share = reads / readers as u64 + u64::from(reads % readers as u64 > t);
            scope.spawn(move || {
                let mut scratch = ProjectScratch::new();
                let mut out = Vec::new();
                for _ in 0..share {
                    if snapshot_path {
                        r.project_many_into(&h, probe, 3, &mut scratch, &mut out).unwrap();
                    } else {
                        r.project(&h, probe.to_vec(), 3).unwrap();
                    }
                }
            });
        }
    });
    let snap = router.pool_snapshot().unwrap();
    pool.shutdown();
    snap
}

/// Series-7 workload: one stream, one long batched feed, optionally
/// capped. Returns the pool snapshot for the bounded-signature asserts.
fn run_bounded(ds: &Dataset, max_landmarks: usize, eviction: EvictionPolicy) -> PoolSnapshot {
    let (pool, router) = spawn_pool(1);
    let cfg = StreamConfig {
        max_landmarks,
        eviction,
        expected_m: if max_landmarks > 0 { max_landmarks + 1 } else { ds.n() },
        expected_batch: 8,
        ..batch_cfg()
    };
    let h = router.open_stream("bounded", ds.dim(), cfg).unwrap();
    router.ingest_all(&h, ds.x.as_slice(), ds.dim(), 8).unwrap();
    let snap = router.pool_snapshot().unwrap();
    pool.shutdown();
    snap
}

/// Series-8 workload: one stream, one long batched feed, served by the
/// given engine tier. Returns the pool snapshot for the tier-signature
/// asserts.
fn run_tier(ds: &Dataset, tier: StreamTier) -> PoolSnapshot {
    let (pool, router) = spawn_pool(1);
    let cfg = StreamConfig {
        tier,
        expected_m: ds.n(),
        expected_batch: 8,
        ..batch_cfg()
    };
    let h = router.open_stream("tiered", ds.dim(), cfg).unwrap();
    router.ingest_all(&h, ds.x.as_slice(), ds.dim(), 8).unwrap();
    let snap = router.pool_snapshot().unwrap();
    pool.shutdown();
    snap
}

fn main() {
    let mut b = Bench::new();
    let fast = std::env::var("INKPCA_BENCH_FAST").is_ok();
    let n_streams = 4usize;

    // Series 1: shard scaling on the PR 2 workload (batch 1).
    let n_scaling = if fast { 60 } else { 160 };
    let scaling_sets: Vec<Dataset> = (0..n_streams)
        .map(|s| {
            let mut ds = load("yeast", n_scaling, 100 + s as u64).unwrap();
            ds.standardize();
            ds
        })
        .collect();
    for shards in [1usize, 2, 4] {
        b.case(&format!("shards/ingest_4streams/shards{shards}"), || {
            run_batched(&scaling_sets, &scaling_cfg(), shards, 1).accepted
        });
    }

    // Series 2: batch-size ladder on the 2-shard/4-stream topology.
    // Short streams (update math cheap) — the per-point overhead the
    // batch amortizes is the dominant cost at batch 1.
    let n_batchwl = if fast { 24 } else { 32 };
    let batch_sets: Vec<Dataset> = (0..n_streams)
        .map(|s| {
            let mut ds = load("yeast", n_batchwl, 200 + s as u64).unwrap();
            ds.standardize();
            ds
        })
        .collect();
    // Post-seed accepts only — the seeding buffer copies are not
    // counted by the per-stream metrics.
    let expected: u64 = (n_streams * (n_batchwl - 4)) as u64;
    for batch in [1usize, 8, 64] {
        b.case(&format!("shards/ingest_4streams_batch{batch}/shards2"), || {
            run_batched(&batch_sets, &batch_cfg(), 2, batch).accepted
        });
        // Correctness guard: every post-seed point of every stream lands.
        assert_eq!(run_batched(&batch_sets, &batch_cfg(), 2, batch).accepted, expected);
    }

    // Series 3: fire-and-forget on the same workload.
    b.case("shards/ingest_4streams_async/shards2", || {
        run_async(&batch_sets, &batch_cfg(), 2)
    });

    // Series 4: the blocked rank-b update isolated — forced fused vs
    // forced sequential back-rotation at batch 8 and 64, entries
    // pre-sized at open. The workspace-counted GEMM rollup is the
    // acceptance gauge: fused must dispatch strictly fewer.
    for batch in [8usize, 64] {
        let mut gemms = [0u64; 2];
        for (i, (label, rot)) in
            [("fusedrot", BatchRotation::Fused), ("seqrot", BatchRotation::Sequential)]
                .iter()
                .enumerate()
        {
            let cfg = rot_cfg(*rot, n_batchwl, batch);
            b.case(&format!("shards/ingest_4streams_batch{batch}_{label}/shards2"), || {
                run_batched(&batch_sets, &cfg, 2, batch).accepted
            });
            let snap = run_batched(&batch_sets, &cfg, 2, batch);
            assert_eq!(snap.accepted, expected);
            gemms[i] = snap.ws_engine_gemms;
        }
        println!(
            "batch {batch}: back-rotation GEMMs fused={} sequential={} ({}x amortization)",
            gemms[0],
            gemms[1],
            if gemms[0] > 0 { gemms[1] / gemms[0].max(1) } else { 0 }
        );
        assert!(
            gemms[0] < gemms[1],
            "fused batch-{batch} run must dispatch fewer back-rotation GEMMs \
             (fused {} vs sequential {})",
            gemms[0],
            gemms[1]
        );
    }

    // Series 5: elastic topology — the same batched workload before,
    // during and after a live 2→4 shard grow. "during" pays the ring
    // change, the entry migrations and the redirected handles while
    // the feed keeps flowing; "after" is the steady-state payoff.
    for (label, mode) in
        [("before", GrowMode::Before), ("during", GrowMode::During), ("after", GrowMode::After)]
    {
        b.case(&format!("shards/grow_2to4_{label}/4streams"), || {
            run_grow(&batch_sets, &batch_cfg(), 8, mode).accepted
        });
        // Correctness guard: a grow must lose no points, and the
        // "during" run must actually have exercised migration.
        let snap = run_grow(&batch_sets, &batch_cfg(), 8, mode);
        assert_eq!(snap.accepted, expected, "grow mode {label} lost points");
        match mode {
            GrowMode::Before => assert_eq!(snap.shards, 2),
            _ => assert_eq!(snap.shards, 4),
        }
        if mode == GrowMode::During {
            assert!(
                snap.migrations > 0,
                "a 2→4 grow with 4 open streams must migrate at least one stream"
            );
            println!(
                "grow during: {} migrations, {} tombstone-forwarded commands",
                snap.migrations, snap.forwards
            );
        }
    }

    // Series 6: the lock-free read path under a read-heavy (95:5)
    // serving mix, reader threads 1/2/4/8, snapshot vs worker path.
    let serve_ds = &batch_sets[0];
    let (s6_reads, s6_writes) = if fast { (950u64, 50usize) } else { (3800u64, 200usize) };
    let mut snapshot_medians: Vec<(usize, f64)> = Vec::new();
    for readers in [1usize, 2, 4, 8] {
        for (label, snapshot_path) in [("snapshot", true), ("worker", false)] {
            let stats = b.case(&format!("shards/read95_{label}_r{readers}/shards2"), || {
                let snap = run_read_heavy(serve_ds, readers, s6_reads, s6_writes, snapshot_path);
                snap.snapshot_reads + snap.worker_reads
            });
            if snapshot_path {
                snapshot_medians.push((readers, stats.median_ns));
            }
        }
    }
    // Attribution guard (outside the timed region): the snapshot series
    // must never touch a worker queue — flat `worker_reads` next to a
    // full `snapshot_reads` budget is the read path's acceptance
    // signature — and the worker series is its exact mirror.
    let snap = run_read_heavy(serve_ds, 4, s6_reads, s6_writes, true);
    assert_eq!(snap.worker_reads, 0, "snapshot reads leaked onto the worker queue");
    assert_eq!(snap.snapshot_reads, s6_reads);
    let snap = run_read_heavy(serve_ds, 4, s6_reads, s6_writes, false);
    assert_eq!(snap.worker_reads, s6_reads);
    assert_eq!(snap.snapshot_reads, 0);
    // Reader scaling: the medians land in the JSON trajectory; here we
    // only pin the lock-free claim — adding readers must not *degrade*
    // the fixed read budget's wall time (a contended path would).
    let solo = snapshot_medians[0].1;
    let (best_r, best) = snapshot_medians[1..]
        .iter()
        .copied()
        .fold((1usize, f64::INFINITY), |a, b| if b.1 < a.1 { b } else { a });
    println!(
        "read95 snapshot path: 1 reader median {:.3} ms, best multi-reader (r={}) {:.3} ms ({:.2}x)",
        solo / 1e6,
        best_r,
        best / 1e6,
        solo / best
    );
    assert!(
        best <= solo * 1.25,
        "snapshot read path degraded under reader concurrency: 1 reader {solo} ns, \
         best multi-reader {best} ns"
    );

    // Series 7: bounded-memory streaming — fixed landmark budget vs
    // unbounded growth on one long feed.
    let n_bounded = if fast { 240 } else { 600 };
    let cap = 48usize;
    let mut bounded_ds = load("yeast", n_bounded, 700).unwrap();
    bounded_ds.standardize();
    for (label, max, ev) in [
        ("off", 0usize, EvictionPolicy::Off),
        ("uniform", cap, EvictionPolicy::Uniform),
        ("leverage", cap, EvictionPolicy::LeverageScore),
    ] {
        b.case(&format!("shards/bounded_{label}/1stream"), || {
            run_bounded(&bounded_ds, max, ev).accepted
        });
    }
    // Bounded signature (outside the timed region): m pinned at the
    // cap, evictions accounting for everything past it, and a resident
    // footprint well under the unbounded run's.
    let unbounded = run_bounded(&bounded_ds, 0, EvictionPolicy::Off);
    for ev in [EvictionPolicy::Uniform, EvictionPolicy::LeverageScore] {
        let snap = run_bounded(&bounded_ds, cap, ev);
        let g = &snap.per_stream[0];
        assert_eq!(g.m, cap, "{} run did not hold the cap", ev.name());
        assert!(snap.evictions > 0, "{} run never evicted", ev.name());
        assert_eq!(
            snap.accepted,
            unbounded.accepted,
            "{} run accepted a different point count",
            ev.name()
        );
        assert!(
            snap.total_ws_bytes * 2 < unbounded.total_ws_bytes,
            "{} bounded run resident bytes {} not well under unbounded {}",
            ev.name(),
            snap.total_ws_bytes,
            unbounded.total_ws_bytes
        );
        println!(
            "bounded {}: m={} evictions={} sufficiency_gap={:.3e} bytes={} (unbounded {})",
            ev.name(),
            g.m,
            snap.evictions,
            g.sufficiency_gap,
            snap.total_ws_bytes,
            unbounded.total_ws_bytes
        );
    }

    // Series 8: the engine-tier ladder at two stream lengths. The
    // exact rows grow superlinearly with the feed (every point enlarges
    // the eigensystem it updates); the rff rows are the flat-memory
    // sketch whose per-point cost must NOT grow with m; shadow runs
    // both engines on every point.
    let tier_sizes: [usize; 2] = if fast { [128, 512] } else { [512, 2048] };
    let rff_tier = StreamTier::Rff { features: 256, sketch_r: 16 };
    let mut rff_per_point: Vec<f64> = Vec::new();
    for &n in &tier_sizes {
        let mut tier_ds = load("yeast", n, 800).unwrap();
        tier_ds.standardize();
        for (label, tier) in [
            ("exact", StreamTier::Exact),
            ("rff", rff_tier),
            ("shadow", StreamTier::Shadow { sample: 8 }),
        ] {
            let stats = b.case(&format!("shards/tier_{label}/m{n}"), || {
                run_tier(&tier_ds, tier).accepted
            });
            if label == "rff" {
                rff_per_point.push(stats.median_ns / n as f64);
            }
        }
        // Tier signatures (outside the timed region): the sketch
        // accepts every post-seed point (no rank-deficiency
        // exclusion), and the shadow run's probes populated the
        // pool-wide divergence gauge.
        let snap = run_tier(&tier_ds, rff_tier);
        assert_eq!(snap.accepted, (n - 4) as u64, "rff run at m={n} dropped points");
        let snap = run_tier(&tier_ds, StreamTier::Shadow { sample: 8 });
        assert!(snap.max_divergence.is_some(), "shadow run at m={n} never probed");
    }
    println!(
        "tier ladder: rff per-point median {:.0} ns at m={} vs {:.0} ns at m={}",
        rff_per_point[0], tier_sizes[0], rff_per_point[1], tier_sizes[1]
    );
    // Generous 3x headroom: the cost model is exactly flat, the bound
    // only absorbs scheduler/allocator noise on small medians.
    assert!(
        rff_per_point[1] <= rff_per_point[0] * 3.0,
        "rff per-point cost must stay flat in m: {rff_per_point:?} across {tier_sizes:?}"
    );

    b.finish();
    if let Err(e) = b.write_json("BENCH_e2e_shards.json") {
        eprintln!("warning: could not write BENCH_e2e_shards.json: {e}");
    } else {
        println!("wrote BENCH_e2e_shards.json");
    }
}
