//! Cross-cutting substrates built in-tree because the offline image
//! carries no rayon/criterion/proptest/rand: a scoped-thread data
//! parallel layer, a deterministic RNG, a micro-bench harness, and a
//! property-test driver.

pub mod bench;
pub mod par;
pub mod prop;
pub mod rng;

pub use rng::Rng;
