//! The single-stream coordinator API, kept source-compatible for every
//! existing caller (CLI, benches, examples, tests) — now a thin wrapper
//! over a 1-shard [`ShardPool`](super::shard::ShardPool): `spawn` opens
//! one default stream on a one-worker pool and every method routes to
//! it. The multi-stream machinery (per-shard workers, stream-keyed
//! routing, pool-level metrics rollups) lives in [`super::shard`]; the
//! per-stream kernel is owned by the stream entry through an `Arc` —
//! the old per-coordinator `Box::leak` is gone.

use std::time::Duration;

use crate::data::StreamSource;
use crate::kpca::{EvictionPolicy, KpcaStats};
use crate::linalg::Norms;

use super::drift::DriftPoint;
use super::engine::StreamTier;
use super::metrics::MetricsReport;
use super::persist::PersistConfig;
use super::router::EnginePolicy;
use super::shard::{
    PoolConfig, RestoreReport, ShardPool, StreamConfig, StreamHandle, StreamRouter,
};

/// Kernel selection (constructed inside the owning shard worker).
#[derive(Clone, Debug)]
pub enum KernelConfig {
    Rbf { sigma: f64 },
    /// RBF with the paper's median heuristic computed over the seed.
    RbfMedian,
    Linear,
    Polynomial { degree: u32, offset: f64 },
    Laplacian { sigma: f64 },
}

impl KernelConfig {
    /// Static family label (matches `Kernel::name` of the kernel the
    /// config builds) — snapshot/metrics paths, no allocation.
    pub fn name(&self) -> &'static str {
        match self {
            KernelConfig::Rbf { .. } | KernelConfig::RbfMedian => "rbf",
            KernelConfig::Linear => "linear",
            KernelConfig::Polynomial { .. } => "poly",
            KernelConfig::Laplacian { .. } => "laplacian",
        }
    }
}

/// Where the hot rotation runs.
#[derive(Clone, Debug, Default)]
pub enum EngineConfig {
    #[default]
    Native,
    /// PJRT engine from AOT artifacts at `dir`, routed per `policy`.
    Pjrt { dir: String, policy: EnginePolicy },
}

/// Single-stream coordinator configuration (the historical surface:
/// stream knobs and pool knobs in one struct, split internally).
#[derive(Clone, Debug)]
pub struct Config {
    pub kernel: KernelConfig,
    pub mean_adjust: bool,
    pub engine: EngineConfig,
    /// Bounded channel capacity (ingest backpressure depth).
    pub queue: usize,
    /// Seed examples accumulated before the batch initialization.
    pub seed_points: usize,
    /// Drift measurement cadence (accepted points; 0 = off).
    pub drift_every: usize,
    /// Snapshot publication cadence on the sequential ingest path
    /// (accepted points; 0 disables the cadence — seed completion,
    /// batch flushes and `sync` still publish). See
    /// [`StreamConfig::publish_every`].
    pub publish_every: usize,
    /// Wall-clock snapshot staleness bound: publish on the next accept
    /// once this much time has passed since the last publication, even
    /// if the count cadence hasn't been reached. `None` disables. See
    /// [`StreamConfig::publish_after`].
    pub publish_after: Option<Duration>,
    /// Durability: snapshot directory + WAL fsync policy. `None` (the
    /// default) runs fully in-memory, exactly as before.
    pub persist: Option<PersistConfig>,
    /// Landmark cap for bounded-memory streaming (0 = unbounded). See
    /// [`StreamConfig::max_landmarks`].
    pub max_landmarks: usize,
    /// Eviction policy applied at the cap. See
    /// [`StreamConfig::eviction`].
    pub eviction: EvictionPolicy,
    /// Which stream engine serves the default stream. See
    /// [`StreamConfig::tier`].
    pub tier: StreamTier,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            kernel: KernelConfig::RbfMedian,
            mean_adjust: true,
            engine: EngineConfig::Native,
            queue: 64,
            seed_points: 20,
            drift_every: 0,
            publish_every: 64,
            publish_after: None,
            persist: None,
            max_landmarks: 0,
            eviction: EvictionPolicy::Off,
            tier: StreamTier::Exact,
        }
    }
}

impl Config {
    /// Split into the pool-level and per-stream halves (a 1-shard pool
    /// reproduces the historical single-worker behaviour exactly).
    pub fn split(&self) -> (PoolConfig, StreamConfig) {
        (
            PoolConfig {
                shards: 1,
                queue: self.queue,
                engine: self.engine.clone(),
                persist: self.persist.clone(),
                ..PoolConfig::default()
            },
            StreamConfig {
                kernel: self.kernel.clone(),
                mean_adjust: self.mean_adjust,
                seed_points: self.seed_points,
                drift_every: self.drift_every,
                publish_every: self.publish_every,
                publish_after: self.publish_after,
                max_landmarks: self.max_landmarks,
                eviction: self.eviction,
                tier: self.tier,
                ..StreamConfig::default()
            },
        )
    }
}

/// Reply to an ingest request.
#[derive(Clone, Copy, Debug)]
pub struct IngestReply {
    pub accepted: bool,
    /// Eigensystem size after the request.
    pub m: usize,
    /// True while the point was only buffered toward the seed batch.
    pub seeding: bool,
}

/// Reply to a batched ingest: how the batch's points split. One reply
/// per *batch*, not per point — the amortization `ingest_many` exists
/// for.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchReply {
    /// Points that joined the eigensystem.
    pub accepted: usize,
    /// Points excluded as rank-deficient (§5.1).
    pub excluded: usize,
    /// Points consumed while the stream was still seeding.
    pub seeded: usize,
    /// Eigensystem size (or buffered seed count) after the batch.
    pub m: usize,
}

/// Point-in-time view of a stream's state.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub m: usize,
    pub dim: usize,
    /// Kernel family label (static — no allocation on this path).
    pub kernel: &'static str,
    /// Engine tier serving the stream (`"exact"`/`"rff"`/`"shadow"`).
    pub tier: &'static str,
    pub top_values: Vec<f64>,
    pub stats: KpcaStats,
    pub drift: Option<DriftPoint>,
    /// (native, pjrt) rotation dispatch counts of the owning shard.
    pub engine_calls: (u64, u64),
}

/// The stream id the single-stream wrapper opens on its pool.
const DEFAULT_STREAM: &str = "default";

/// Handle to a running single-stream coordinator (a 1-shard pool with
/// one open stream, addressed through its resolved [`StreamHandle`]).
pub struct Coordinator {
    router: StreamRouter,
    handle: StreamHandle,
    pool: ShardPool,
}

impl Coordinator {
    /// Spawn the worker and open the default stream.
    pub fn spawn(cfg: Config, dim: usize) -> Coordinator {
        let (pool_cfg, stream_cfg) = cfg.split();
        let pool = ShardPool::spawn(pool_cfg);
        let router = pool.router();
        let handle = router
            .open_stream(DEFAULT_STREAM, dim, stream_cfg)
            .expect("fresh 1-shard pool accepts its default stream");
        Coordinator { router, handle, pool }
    }

    /// Spawn a coordinator and recover the default stream from
    /// `cfg.persist`'s snapshot directory: checkpoints are loaded, the
    /// WAL suffix replayed, and the handle re-resolved. If the
    /// directory holds no trace of the default stream (first boot, or
    /// everything was cleanly closed), a fresh stream is opened —
    /// restore-then-serve is safe to run unconditionally.
    ///
    /// Errors if `cfg.persist` is `None` or the restore itself fails.
    pub fn restore(cfg: Config, dim: usize) -> Result<(Coordinator, RestoreReport), String> {
        if cfg.persist.is_none() {
            return Err("durability not configured (no snapshot dir)".into());
        }
        let (pool_cfg, stream_cfg) = cfg.split();
        let pool = ShardPool::spawn(pool_cfg);
        let router = pool.router();
        let report = router.restore_pool()?;
        let handle = match report.handles.iter().find(|h| h.id() == DEFAULT_STREAM) {
            Some(h) => h.clone(),
            None => router.open_stream(DEFAULT_STREAM, dim, stream_cfg)?,
        };
        Ok((Coordinator { router, handle, pool }, report))
    }

    /// Checkpoint the default stream at a consistent cut. Returns the
    /// number of bytes written — see
    /// [`StreamRouter::checkpoint_stream`].
    pub fn checkpoint(&self) -> Result<u64, String> {
        self.router.checkpoint_stream(&self.handle)
    }

    /// Checkpoint every live stream and rotate the WAL on full success
    /// — see [`StreamRouter::checkpoint_all`].
    pub fn checkpoint_all(&self) -> Result<usize, String> {
        self.router.checkpoint_all()
    }

    /// Ingest one example (blocks under backpressure).
    pub fn ingest(&self, x: Vec<f64>) -> Result<IngestReply, String> {
        self.router.ingest(&self.handle, x)
    }

    /// Ingest a whole batch (`xs` is `b × dim` row-major) as one
    /// command — see [`StreamRouter::ingest_many`].
    pub fn ingest_many(&self, xs: Vec<f64>) -> Result<BatchReply, String> {
        self.router.ingest_many(&self.handle, xs)
    }

    /// Fire-and-forget ingest — see [`StreamRouter::ingest_async`].
    pub fn ingest_async(&self, x: Vec<f64>) -> Result<(), String> {
        self.router.ingest_async(&self.handle, x)
    }

    /// Drive a whole flat `n × dim` feed in `batch`-sized commands —
    /// see [`StreamRouter::ingest_all`].
    pub fn ingest_all(&self, flat: &[f64], dim: usize, batch: usize) -> Result<BatchReply, String> {
        self.router.ingest_all(&self.handle, flat, dim, batch)
    }

    /// Barrier + deferred-error drain for fire-and-forget ingest.
    pub fn sync(&self) -> Result<u64, String> {
        self.router.sync(&self.handle)
    }

    /// Project a point onto the current top-`r` components (worker
    /// path: fully fresh, serialized behind ingests).
    pub fn project(&self, x: Vec<f64>, r: usize) -> Result<Vec<f64>, String> {
        self.router.project(&self.handle, x, r)
    }

    /// Project through the published snapshot — lock-free, never
    /// enqueues a command. See [`StreamRouter::project_snapshot`] for
    /// the freshness contract (`sync` first for read-your-writes).
    pub fn project_snapshot(&self, x: &[f64], r: usize) -> Result<Vec<f64>, String> {
        self.router.project_snapshot(&self.handle, x, r)
    }

    /// Batched lock-free projection (`ys` is `b × dim` row-major,
    /// result `b × r_eff` row-major) — see
    /// [`StreamRouter::project_many`].
    pub fn project_many(&self, ys: &[f64], r: usize) -> Result<Vec<f64>, String> {
        self.router.project_many(&self.handle, ys, r)
    }

    /// Force an immediate drift measurement.
    pub fn measure_drift(&self) -> Result<DriftPoint, String> {
        self.router.measure_drift(&self.handle)
    }

    pub fn snapshot(&self) -> Result<Snapshot, String> {
        self.router.snapshot(&self.handle)
    }

    pub fn metrics(&self) -> Result<MetricsReport, String> {
        self.router.metrics(&self.handle)
    }

    /// Drain a whole stream source through the coordinator, returning
    /// the number of accepted examples.
    pub fn ingest_stream(&self, src: &mut dyn StreamSource) -> Result<usize, String> {
        let mut accepted = 0;
        while let Some(x) = src.next_example() {
            if self.ingest(x)?.accepted {
                accepted += 1;
            }
        }
        Ok(accepted)
    }

    /// Stop the worker and return final stats.
    pub fn shutdown(self) -> KpcaStats {
        let stats = self.router.close_stream(&self.handle).unwrap_or_default();
        self.pool.shutdown();
        stats
    }
}

/// Convenience: drift norms of a snapshot, if measured.
pub fn snapshot_drift(snap: &Snapshot) -> Option<Norms> {
    snap.drift.map(|d| d.norms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::data::SliceSource;

    fn config() -> Config {
        Config { seed_points: 6, drift_every: 4, ..Config::default() }
    }

    #[test]
    fn end_to_end_stream_session() {
        let ds = yeast_like(30, 1);
        let dim = ds.dim();
        let coord = Coordinator::spawn(config(), dim);
        let mut src = SliceSource::new(ds);
        let accepted = coord.ingest_stream(&mut src).unwrap();
        assert_eq!(accepted, 30);
        let snap = coord.snapshot().unwrap();
        assert_eq!(snap.m, 30);
        assert!(!snap.top_values.is_empty());
        assert!(snap.drift.is_some());
        assert!(snap.drift.unwrap().norms.frobenius < 1e-7);
        let report = coord.metrics().unwrap();
        assert_eq!(report.accepted as usize, 30 - 6); // post-seed accepts
        // Hot-path gauges are live: buffers resident, growth amortized
        // (far fewer growth events than rank-one updates performed).
        assert!(report.ws_bytes_resident > 0);
        assert!(report.reallocs_per_update < 1.0, "report {report}");
        let stats = coord.shutdown();
        assert_eq!(stats.accepted, 30);
    }

    #[test]
    fn projection_after_seeding() {
        let ds = yeast_like(20, 2);
        let dim = ds.dim();
        let coord = Coordinator::spawn(config(), dim);
        // Before seeding completes, projection errors cleanly.
        assert!(coord.project(vec![0.1; dim], 2).is_err());
        for i in 0..20 {
            coord.ingest(ds.x.row(i).to_vec()).unwrap();
        }
        let scores = coord.project(vec![0.3; dim], 3).unwrap();
        assert_eq!(scores.len(), 3);
        // The lock-free path agrees with the worker path once synced.
        coord.sync().unwrap();
        let snap_scores = coord.project_snapshot(&vec![0.3; dim], 3).unwrap();
        assert_eq!(snap_scores.len(), 3);
        for (a, b) in scores.iter().zip(&snap_scores) {
            assert!((a - b).abs() < 1e-12, "worker {a} vs snapshot {b}");
        }
        let many = coord.project_many(&vec![0.3; dim], 3).unwrap();
        assert_eq!(many, snap_scores);
        coord.shutdown();
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let coord = Coordinator::spawn(config(), 4);
        assert!(coord.ingest(vec![0.0; 3]).is_err());
        let report = coord.metrics().unwrap();
        assert_eq!(report.errors, 1);
        coord.shutdown();
    }

    #[test]
    fn explicit_drift_measurement() {
        let ds = yeast_like(12, 3);
        let coord = Coordinator::spawn(Config { seed_points: 6, ..Config::default() }, ds.dim());
        assert!(coord.measure_drift().is_err()); // not seeded yet
        for i in 0..12 {
            coord.ingest(ds.x.row(i).to_vec()).unwrap();
        }
        let d = coord.measure_drift().unwrap();
        assert_eq!(d.m, 12);
        assert!(d.norms.frobenius < 1e-8);
        coord.shutdown();
    }

    #[test]
    fn shutdown_idempotent_under_drop() {
        let coord = Coordinator::spawn(config(), 3);
        drop(coord); // must not hang or panic
    }

    #[test]
    fn batched_session_matches_sequential_counters() {
        let ds = yeast_like(30, 9);
        let dim = ds.dim();
        let coord = Coordinator::spawn(config(), dim);
        let flat = ds.x.as_slice();
        let mut i = 0;
        while i < 30 {
            let end = (i + 7).min(30);
            let reply = coord.ingest_many(flat[i * dim..end * dim].to_vec()).unwrap();
            assert_eq!(reply.seeded + reply.accepted + reply.excluded, end - i);
            i = end;
        }
        let snap = coord.snapshot().unwrap();
        assert_eq!(snap.m, 30);
        assert_eq!(snap.kernel, "rbf");
        assert_eq!(snap.tier, "exact");
        let report = coord.metrics().unwrap();
        assert_eq!(report.accepted as usize, 30 - 6);
        assert_eq!(report.async_errors, 0);
        let stats = coord.shutdown();
        assert_eq!(stats.accepted, 30);
    }
}
