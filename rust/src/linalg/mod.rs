//! Dense linear-algebra substrate, built from scratch: matrix type,
//! borrowed matrix views (the zero-allocation hot-path currency),
//! blocked/parallel BLAS-3 with `*_into` variants, Householder
//! tridiagonalization, implicit-QL tridiagonal eigensolver, full
//! symmetric `eigh`, Cholesky with rank-one up/downdates, and the three
//! norms the paper's figures report.

pub mod cholesky;
pub mod eigh;
pub mod gemm;
pub mod householder;
pub mod matrix;
pub mod norms;
pub mod pack;
pub mod tridiag;
pub mod view;

pub use cholesky::{Cholesky, PackedCholesky};
pub use eigh::{eigh, eigvalsh, Eigh};
pub use gemm::{
    gemv, gemv_into, gemv_t, gemv_t_into, matmul, matmul_into, matmul_into_buf,
    matmul_into_unpacked, matmul_nt, matmul_nt_into, matmul_nt_into_buf, matmul_nt_into_unpacked,
    matmul_tn_into, matmul_tn_into_buf, matmul_tn_into_unpacked, syrk, transpose_into,
};
pub use matrix::{dot, norm2, Mat};
pub use norms::{
    frobenius, orthogonality_defect, psd_norms, spectral_sym, sym_norms, trace_sym, Norms,
};
pub use pack::PackBuffers;
pub use view::{MatView, MatViewMut};
