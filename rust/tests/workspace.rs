//! Zero-allocation hot-path guarantees: the workspace update path must
//! reproduce the allocating path bit-for-bit (they share one core), and
//! a warmed `UpdateWorkspace` must never touch the allocator again at
//! fixed eigensystem size.

use inkpca::data::synthetic::yeast_like;
use inkpca::kernels::{gram, Kernel, Linear, Polynomial, Rbf};
use inkpca::kpca::{center_gram, IncrementalKpca};
use inkpca::linalg::{eigh, orthogonality_defect};
use inkpca::rankone::{
    expand_eigensystem, expand_eigensystem_ws, flush_rotation_ws, rank_one_update,
    rank_one_update_fused_ws, rank_one_update_ws, EigenBasis, NativeRotate, UpdateWorkspace,
};
use inkpca::util::prop::{check, ensure};
use inkpca::util::Rng;

fn random_kernel(rng: &mut Rng) -> Box<dyn Kernel> {
    match rng.below(3) {
        0 => Box::new(Rbf { sigma: rng.range(0.5, 3.0) }),
        1 => Box::new(Linear),
        _ => Box::new(Polynomial { degree: 2, offset: rng.range(0.5, 2.0) }),
    }
}

/// The workspace path and the allocating path must agree to ≤ 1e-12 on
/// the same update sequence, across RBF/linear/polynomial kernels and
/// both mean-adjust modes (the eigensystems come from real centered /
/// uncentered Gram matrices), including a mid-stream expansion step.
#[test]
fn prop_workspace_path_reproduces_allocating_path() {
    check("workspace==alloc", 24, |rng| {
        let n = 6 + rng.below(10);
        let ds = yeast_like(n, rng.next_u64());
        let kern = random_kernel(rng);
        let mean_adjust = rng.uniform() < 0.5;
        let k = gram(kern.as_ref(), &ds.x);
        let k_used = if mean_adjust { center_gram(&k) } else { k };
        let eg = eigh(&k_used).map_err(|e| e.to_string())?;

        let mut vals_a = eg.values.clone();
        let mut vecs_a = eg.vectors.clone();
        let mut vals_w = eg.values.clone();
        let mut basis_w = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws = UpdateWorkspace::new();

        for step in 0..6 {
            if step == 3 {
                // An expansion rides along mid-stream, as in the real
                // algorithms.
                expand_eigensystem(&mut vals_a, &mut vecs_a, 0.5);
                expand_eigensystem_ws(&mut vals_w, &mut basis_w, 0.5, &mut ws);
            }
            let m = vecs_a.rows();
            let v: Vec<f64> = (0..m).map(|_| rng.range(-1.0, 1.0)).collect();
            let sigma =
                if step % 2 == 0 { rng.range(0.2, 2.0) } else { rng.range(-2.0, -0.2) };
            rank_one_update(&mut vals_a, &mut vecs_a, sigma, &v, &NativeRotate)
                .map_err(|e| e.to_string())?;
            rank_one_update_ws(&mut vals_w, &mut basis_w, sigma, &v, &NativeRotate, &mut ws)
                .map_err(|e| e.to_string())?;
        }

        for (a, b) in vals_a.iter().zip(vals_w.iter()) {
            ensure((a - b).abs() <= 1e-12 * (1.0 + a.abs()), || {
                format!("kernel {} eigenvalue {a} vs {b}", kern.name())
            })?;
        }
        let diff = basis_w.max_abs_diff(&vecs_a);
        ensure(diff <= 1e-12, || {
            format!("kernel {} adjust={mean_adjust} eigenvector diff {diff}", kern.name())
        })?;
        ensure(orthogonality_defect(&basis_w) < 1e-8, || "orthogonality lost".to_string())
    });
}

/// A warmed workspace performs zero buffer reallocations over 100
/// consecutive updates at fixed eigensystem size — the allocator has
/// left the steady state.
#[test]
fn warm_workspace_zero_reallocations_over_100_updates() {
    let n = 24;
    let ds = yeast_like(n, 5);
    let kern = Rbf { sigma: 1.0 };
    let k = gram(&kern, &ds.x);
    let eg = eigh(&k).unwrap();
    let mut vals = eg.values.clone();
    let mut basis = EigenBasis::from_mat(eg.vectors.clone());
    let mut ws = UpdateWorkspace::new();
    ws.reserve(n, n);
    assert_eq!(ws.reallocs(), 0, "reserve must not count as growth");

    let mut rng = Rng::new(11);
    let mut v = vec![0.0; n];
    for step in 0..100 {
        for x in v.iter_mut() {
            *x = rng.range(-1.0, 1.0);
        }
        let sigma = if step % 2 == 0 { 0.8 } else { -0.8 };
        rank_one_update_ws(&mut vals, &mut basis, sigma, &v, &NativeRotate, &mut ws).unwrap();
    }
    assert_eq!(
        ws.reallocs(),
        0,
        "workspace buffers reallocated on the steady-state hot path"
    );
    assert_eq!(basis.reallocs(), 0, "eigenbasis reallocated at fixed size");
    // The math stayed healthy while the allocator stayed idle.
    assert!(orthogonality_defect(&basis) < 1e-8);
    for w in vals.windows(2) {
        assert!(w[0] <= w[1] + 1e-12);
    }
}

/// The fused rank-b path — accumulate, flush, repeat — performs zero
/// buffer reallocations over 100 update+flush cycles once reserved:
/// the secular scratch, the pending-product double buffer, the rotated
/// basis swap buffer *and the GEMM packing panels* are all warm. This
/// pins the packed GEMM's scratch into the same zero-allocation
/// guarantee the sequential test above established.
#[test]
fn warm_fused_flush_zero_reallocations_over_100_cycles() {
    let n = 24;
    let ds = yeast_like(n, 7);
    let kern = Rbf { sigma: 1.0 };
    let k = gram(&kern, &ds.x);
    let eg = eigh(&k).unwrap();
    let mut vals = eg.values.clone();
    let mut basis = EigenBasis::from_mat(eg.vectors.clone());
    let mut ws = UpdateWorkspace::new();
    ws.reserve(n, n);
    ws.reserve_blocked(n);
    assert_eq!(ws.reallocs(), 0, "reserve must not count as growth");

    let mut rng = Rng::new(13);
    let mut v = vec![0.0; n];
    for cycle in 0..100 {
        // Two fused updates per cycle so both the seed-Q and the Q·W
        // accumulation GEMM run, then a flush (one engine GEMM).
        for step in 0..2 {
            for x in v.iter_mut() {
                *x = rng.range(-1.0, 1.0);
            }
            let sigma = if (cycle + step) % 2 == 0 { 0.8 } else { -0.8 };
            rank_one_update_fused_ws(&mut vals, &mut basis, sigma, &v, &NativeRotate, &mut ws)
                .unwrap();
        }
        flush_rotation_ws(&mut basis, &NativeRotate, &mut ws);
    }
    assert_eq!(ws.reallocs(), 0, "fused flush cycle reallocated on the steady-state path");
    assert_eq!(basis.reallocs(), 0, "eigenbasis reallocated at fixed size");
    assert!(orthogonality_defect(&basis) < 1e-7);
    for w in vals.windows(2) {
        assert!(w[0] <= w[1] + 1e-12);
    }
}

/// Streaming growth (expansion every push) reallocates only on capacity
/// doublings — amortized O(1) per accepted example, not copy-per-step.
#[test]
fn streaming_growth_reallocs_are_logarithmic() {
    let ds = yeast_like(80, 9);
    let kern = Rbf { sigma: 1.0 };
    let seed = ds.x.submatrix(4, ds.dim());
    let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    for i in 4..ds.n() {
        inc.push(ds.x.row(i)).unwrap();
    }
    assert_eq!(inc.len(), 80);
    let pushes = (ds.n() - 4) as u64;
    // Each adjusted push performs 4 rank-one updates + 1 expansion; a
    // copy-per-step design would pay ≥ 5 allocations per push. Doubling
    // growth keeps total growth events well under one per push.
    let reallocs = inc.hot_path_reallocs();
    assert!(reallocs < pushes / 2, "reallocs {reallocs} vs pushes {pushes}");
    // And the result is still the exact algorithm.
    let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
    assert!(drift < 1e-7, "drift {drift}");
}

/// The engine-visible workspace diagnostics are wired through the
/// incremental state.
#[test]
fn hot_path_gauges_report_residency() {
    let ds = yeast_like(16, 3);
    let kern = Rbf { sigma: 1.0 };
    let seed = ds.x.submatrix(4, ds.dim());
    let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    for i in 4..ds.n() {
        inc.push(ds.x.row(i)).unwrap();
    }
    assert!(inc.hot_path_bytes() > 0);
    assert!(inc.workspace().bytes_resident() > 0);
}
