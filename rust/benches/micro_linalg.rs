//! Micro-benchmarks of the substrate hot paths: blocked GEMM, the
//! symmetric eigensolver, the secular root finder and the rank-one
//! update in both forms — the allocating compatibility path vs the
//! zero-allocation workspace path — at sizes up to m=512. Emits
//! `BENCH_rankone.json` so the perf trajectory is recorded run-over-run.

use inkpca::linalg::{eigh, matmul, Mat};
use inkpca::rankone::{
    rank_one_update, rank_one_update_ws, EigenBasis, NativeRotate, UpdateWorkspace,
};
use inkpca::secular::solve_all;
use inkpca::util::bench::Bench;
use inkpca::util::Rng;

fn rand_mat(n: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(n, n, |_, _| rng.range(-1.0, 1.0))
}

fn rand_sym(n: usize, seed: u64) -> Mat {
    let mut m = rand_mat(n, seed);
    m.symmetrize();
    m
}

fn main() {
    let mut b = Bench::new();
    for n in [128usize, 256, 512] {
        let a = rand_mat(n, 1);
        let c = rand_mat(n, 2);
        b.case(&format!("linalg/gemm/n{n}"), || matmul(&a, &c).max_abs());
    }
    for n in [64usize, 128, 256] {
        let s = rand_sym(n, 3);
        b.case(&format!("linalg/eigh/n{n}"), || eigh(&s).unwrap().values[0]);
    }
    for n in [64usize, 256, 1024] {
        let mut rng = Rng::new(4);
        let mut d: Vec<f64> = (0..n).map(|_| rng.range(0.0, 10.0)).collect();
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let z: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        b.case(&format!("secular/solve_all/n{n}"), || {
            solve_all(&d, &z, 1.5).unwrap().len()
        });
    }

    // Rank-one update: allocating compatibility path vs warmed workspace
    // path, on an *evolving* eigensystem (alternating ±σ keeps the
    // spectrum bounded) so the steady-state allocation behaviour — not a
    // per-sample clone — is what gets measured. The workspace rows must
    // come out measurably faster at m ≥ 512 (acceptance criterion).
    for n in [128usize, 256, 512] {
        let s = rand_sym(n, 5);
        let eg = eigh(&s).unwrap();

        let mut vals_a = eg.values.clone();
        let mut vecs_a = eg.vectors.clone();
        let mut rng_a = Rng::new(6);
        let mut v_a = vec![0.0; n];
        let mut flip_a = false;
        b.case(&format!("rankone/update_alloc/n{n}"), || {
            for x in v_a.iter_mut() {
                *x = rng_a.range(-1.0, 1.0);
            }
            flip_a = !flip_a;
            let sigma = if flip_a { 1.0 } else { -1.0 };
            rank_one_update(&mut vals_a, &mut vecs_a, sigma, &v_a, &NativeRotate)
                .unwrap()
                .solved
        });

        let mut vals_w = eg.values.clone();
        let mut basis = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws = UpdateWorkspace::new();
        ws.reserve(n, n);
        let mut rng_w = Rng::new(6);
        let mut v_w = vec![0.0; n];
        let mut flip_w = false;
        b.case(&format!("rankone/update_ws/n{n}"), || {
            for x in v_w.iter_mut() {
                *x = rng_w.range(-1.0, 1.0);
            }
            flip_w = !flip_w;
            let sigma = if flip_w { 1.0 } else { -1.0 };
            rank_one_update_ws(&mut vals_w, &mut basis, sigma, &v_w, &NativeRotate, &mut ws)
                .unwrap()
                .solved
        });
        assert_eq!(ws.reallocs(), 0, "warmed workspace must stay allocation-free");
    }

    // Expansion: the per-accepted-example grow step, measured on a
    // growing system (each sample adds one eigenpair, as a stream
    // does). The allocating path re-layouts the full matrix per call;
    // the workspace path grows in place — amortized O(1) reallocation,
    // O(m) writes.
    for n in [128usize, 256, 512] {
        let vals0: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let eye = Mat::eye(n);
        let mut vals_a = vals0.clone();
        let mut vecs_a = eye.clone();
        b.case(&format!("rankone/expand_alloc/n{n}"), || {
            let new_val = vals_a.last().unwrap() + 1.0;
            inkpca::rankone::expand_eigensystem(&mut vals_a, &mut vecs_a, new_val);
            vals_a.len()
        });
        let mut vals_w = vals0.clone();
        let mut basis = EigenBasis::from_mat(eye.clone());
        let mut ws = UpdateWorkspace::new();
        b.case(&format!("rankone/expand_ws/n{n}"), || {
            let new_val = vals_w.last().unwrap() + 1.0;
            inkpca::rankone::expand_eigensystem_ws(&mut vals_w, &mut basis, new_val, &mut ws);
            vals_w.len()
        });
    }

    b.finish();
    if let Err(e) = b.write_json("BENCH_rankone.json") {
        eprintln!("warning: could not write BENCH_rankone.json: {e}");
    } else {
        println!("wrote BENCH_rankone.json");
    }
}
