//! Batched-ingest equivalence suite: `ingest_many` and
//! `ingest_async`-then-`sync` must reach exactly the state sequential
//! `ingest` reaches (≤1e-10) across kernel families and batch shapes,
//! including batches that straddle the seeding boundary and batches
//! with mid-batch §5.1 exclusions / deflation-heavy duplicates — plus
//! the zero-realloc steady-state guarantee of the batched hot path.

use inkpca::coordinator::{
    EngineConfig, KernelConfig, PoolConfig, ShardPool, StreamConfig, StreamHandle, StreamRouter,
};
use inkpca::data::synthetic::yeast_like;
use inkpca::data::Dataset;
use inkpca::kernels::{Kernel, Linear, Polynomial, Rbf};
use inkpca::kpca::IncrementalKpca;

fn cfg(kernel: KernelConfig, mean_adjust: bool) -> StreamConfig {
    StreamConfig { kernel, mean_adjust, seed_points: 6, drift_every: 0 }
}

fn drive_sequential(router: &StreamRouter, h: &StreamHandle, ds: &Dataset) {
    for i in 0..ds.n() {
        router.ingest(h, ds.x.row(i).to_vec()).unwrap();
    }
}

fn drive_batched(router: &StreamRouter, h: &StreamHandle, ds: &Dataset, batch: usize) {
    let reply = router.ingest_all(h, ds.x.as_slice(), ds.dim(), batch).unwrap();
    assert_eq!(reply.seeded + reply.accepted + reply.excluded, ds.n());
    assert_eq!(reply.m, ds.n() - reply.excluded);
}

fn drive_async(router: &StreamRouter, h: &StreamHandle, ds: &Dataset) {
    for i in 0..ds.n() {
        router.ingest_async(h, ds.x.row(i).to_vec()).unwrap();
    }
    assert_eq!(router.sync(h).unwrap(), 0, "{}: async stream saw errors", h.id());
}

/// All three ingest shapes against one dataset/kernel/adjust mode; the
/// batched and async streams must match the sequential one ≤ 1e-10 on
/// eigenvalues and projection magnitudes.
fn assert_ingest_shapes_equivalent(kernel: KernelConfig, mean_adjust: bool, seed: u64) {
    let mut ds = yeast_like(27, seed);
    ds.standardize();
    let pool = ShardPool::spawn(PoolConfig { shards: 2, queue: 16, engine: EngineConfig::Native });
    let router = pool.router();
    let hs = router.open_stream("seq", ds.dim(), cfg(kernel.clone(), mean_adjust)).unwrap();
    let h5 = router.open_stream("b5", ds.dim(), cfg(kernel.clone(), mean_adjust)).unwrap();
    let h64 = router.open_stream("b64", ds.dim(), cfg(kernel.clone(), mean_adjust)).unwrap();
    let ha = router.open_stream("asy", ds.dim(), cfg(kernel.clone(), mean_adjust)).unwrap();
    drive_sequential(&router, &hs, &ds);
    drive_batched(&router, &h5, &ds, 5); // straddles the seeding boundary
    drive_batched(&router, &h64, &ds, 64); // whole stream in one command
    drive_async(&router, &ha, &ds);

    let reference = router.snapshot(&hs).unwrap();
    assert_eq!(reference.m, 27);
    let probe = vec![0.3; ds.dim()];
    let ref_proj = router.project(&hs, probe.clone(), 4).unwrap();
    for h in [&h5, &h64, &ha] {
        let snap = router.snapshot(h).unwrap();
        assert_eq!(snap.m, 27, "{:?} {}", kernel, h.id());
        for (got, want) in snap.top_values.iter().zip(&reference.top_values) {
            assert!(
                (got - want).abs() <= 1e-10,
                "{:?} {}: eigenvalue {got} vs {want}",
                kernel,
                h.id()
            );
        }
        let proj = router.project(h, probe.clone(), 4).unwrap();
        for (g, w) in proj.iter().zip(&ref_proj) {
            assert!(
                (g.abs() - w.abs()).abs() <= 1e-10,
                "{:?} {}: projection {g} vs {w}",
                kernel,
                h.id()
            );
        }
    }
    pool.shutdown();
}

#[test]
fn batched_equals_sequential_rbf_adjusted() {
    assert_ingest_shapes_equivalent(KernelConfig::Rbf { sigma: 1.2 }, true, 900);
}

#[test]
fn batched_equals_sequential_linear_unadjusted() {
    assert_ingest_shapes_equivalent(KernelConfig::Linear, false, 901);
}

#[test]
fn batched_equals_sequential_poly_adjusted() {
    assert_ingest_shapes_equivalent(
        KernelConfig::Polynomial { degree: 2, offset: 1.0 },
        true,
        902,
    );
}

/// Duplicate points make the adjusted kernel matrix singular — the
/// deflation path runs *inside* a batch and must stay ≤1e-10 equal to
/// the sequential run through the same points.
#[test]
fn deflation_heavy_batch_matches_sequential() {
    let mut ds = yeast_like(12, 903);
    ds.standardize();
    let dim = ds.dim();
    // points 6.. with two mid-batch duplicates of earlier rows.
    let mut tail: Vec<f64> = Vec::new();
    for i in 6..10 {
        tail.extend_from_slice(ds.x.row(i));
        tail.extend_from_slice(ds.x.row(i - 4)); // duplicate
    }
    let kern = Rbf { sigma: 1.0 };
    let seed = ds.x.submatrix(6, dim);
    let mut seq = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    for chunk in tail.chunks(dim) {
        seq.push(chunk).unwrap();
    }
    let mut bat = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    let out = bat.push_batch(&tail).unwrap();
    assert_eq!(out.accepted + out.excluded, 8);
    assert_eq!(seq.len(), bat.len());
    let diff = bat.reconstruct().max_abs_diff(&seq.reconstruct());
    assert!(diff < 1e-10, "deflation-heavy batch diff {diff}");
    // And the batched run still tracks the batch-recomputed ground
    // truth through the singular stretches.
    let drift = bat.reconstruct().max_abs_diff(&bat.batch_reference());
    assert!(drift < 1e-7, "drift {drift}");
}

/// Batch equivalence across kernel families at the library level, with
/// ragged batch sizes (1, 3, then the rest) against point-by-point.
#[test]
fn ragged_batches_match_sequential_across_kernels() {
    let kernels: Vec<Box<dyn Kernel>> = vec![
        Box::new(Rbf { sigma: 0.9 }),
        Box::new(Linear),
        Box::new(Polynomial { degree: 3, offset: 0.7 }),
    ];
    for (ki, kern) in kernels.iter().enumerate() {
        for &mean_adjust in &[false, true] {
            let mut ds = yeast_like(22, 910 + ki as u64);
            ds.standardize();
            let dim = ds.dim();
            let seed = ds.x.submatrix(5, dim);
            let flat = ds.x.as_slice();
            let mut seq = IncrementalKpca::from_batch(kern.as_ref(), &seed, mean_adjust).unwrap();
            for i in 5..ds.n() {
                seq.push(ds.x.row(i)).unwrap();
            }
            let mut bat = IncrementalKpca::from_batch(kern.as_ref(), &seed, mean_adjust).unwrap();
            bat.push_batch(&flat[5 * dim..6 * dim]).unwrap(); // b = 1
            bat.push_batch(&flat[6 * dim..9 * dim]).unwrap(); // b = 3
            bat.push_batch(&flat[9 * dim..22 * dim]).unwrap(); // b = 13
            assert_eq!(seq.len(), bat.len());
            let diff = bat.reconstruct().max_abs_diff(&seq.reconstruct());
            assert!(
                diff < 1e-10,
                "kernel {} adjust={mean_adjust}: diff {diff}",
                kern.name()
            );
        }
    }
}

/// The zero-realloc steady-state guarantee for the batched path: with
/// the stream pre-sized ([`IncrementalKpca::reserve`]), a batched run
/// must leave every tracked hot-path counter untouched — the workspace
/// and eigenbasis (as in the sequential guarantee) *and* the batch
/// scratch (kernel blocks, row norms, assembly buffers).
#[test]
fn batched_steady_state_is_zero_realloc() {
    let mut ds = yeast_like(46, 920);
    ds.standardize();
    let dim = ds.dim();
    let kern = Rbf { sigma: 1.1 };
    let seed = ds.x.submatrix(6, dim);
    let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    inc.reserve(48, 8);
    let ws0 = inc.hot_path_reallocs();
    let batch0 = inc.batch_reallocs();
    let flat = ds.x.as_slice();
    let mut i = 6;
    while i < ds.n() {
        let end = (i + 8).min(ds.n());
        inc.push_batch(&flat[i * dim..end * dim]).unwrap();
        i = end;
    }
    assert_eq!(inc.len(), 46);
    assert_eq!(inc.hot_path_reallocs(), ws0, "workspace/basis allocated in steady state");
    assert_eq!(inc.batch_reallocs(), batch0, "batch scratch allocated in steady state");
    // The same stream keeps running batch-silent on further batches of
    // the reserved size.
    let extra = yeast_like(8, 921);
    let mut tail = Vec::new();
    for i in 0..2 {
        tail.extend_from_slice(extra.x.row(i));
    }
    inc.push_batch(&tail).unwrap();
    assert_eq!(inc.batch_reallocs(), batch0);
}
