//! The streaming coordinator: a worker thread owning the incremental
//! eigensystem, fed through a *bounded* command channel (backpressure —
//! producers block when the update loop falls behind), with rendezvous
//! replies, periodic drift measurement and latency metrics. This is the
//! L3 event loop; the PJRT runtime (not `Send`) is constructed inside
//! the worker thread.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::data::StreamSource;
use crate::kernels::{median_heuristic, Kernel};
use crate::kpca::{IncrementalKpca, KpcaStats};
use crate::linalg::{Mat, Norms};

use super::drift::{DriftMonitor, DriftPoint};
use super::metrics::{Metrics, MetricsReport};
use super::router::{EnginePolicy, RoutedEngine};

/// Kernel selection (constructed inside the worker thread).
#[derive(Clone, Debug)]
pub enum KernelConfig {
    Rbf { sigma: f64 },
    /// RBF with the paper's median heuristic computed over the seed.
    RbfMedian,
    Linear,
    Polynomial { degree: u32, offset: f64 },
    Laplacian { sigma: f64 },
}

/// Where the hot rotation runs.
#[derive(Clone, Debug, Default)]
pub enum EngineConfig {
    #[default]
    Native,
    /// PJRT engine from AOT artifacts at `dir`, routed per `policy`.
    Pjrt { dir: String, policy: EnginePolicy },
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub kernel: KernelConfig,
    pub mean_adjust: bool,
    pub engine: EngineConfig,
    /// Bounded channel capacity (ingest backpressure depth).
    pub queue: usize,
    /// Seed examples accumulated before the batch initialization.
    pub seed_points: usize,
    /// Drift measurement cadence (accepted points; 0 = off).
    pub drift_every: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            kernel: KernelConfig::RbfMedian,
            mean_adjust: true,
            engine: EngineConfig::Native,
            queue: 64,
            seed_points: 20,
            drift_every: 0,
        }
    }
}

/// Reply to an ingest request.
#[derive(Clone, Copy, Debug)]
pub struct IngestReply {
    pub accepted: bool,
    /// Eigensystem size after the request.
    pub m: usize,
    /// True while the point was only buffered toward the seed batch.
    pub seeding: bool,
}

/// Point-in-time view of the coordinator state.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub m: usize,
    pub dim: usize,
    pub top_values: Vec<f64>,
    pub stats: KpcaStats,
    pub drift: Option<DriftPoint>,
    /// (native, pjrt) rotation dispatch counts.
    pub engine_calls: (u64, u64),
}

enum Command {
    Ingest(Vec<f64>, SyncSender<Result<IngestReply, String>>),
    Project(Vec<f64>, usize, SyncSender<Result<Vec<f64>, String>>),
    MeasureDrift(SyncSender<Result<DriftPoint, String>>),
    Snapshot(SyncSender<Snapshot>),
    Metrics(SyncSender<MetricsReport>),
    Shutdown,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: SyncSender<Command>,
    join: Option<JoinHandle<KpcaStats>>,
}

impl Coordinator {
    /// Spawn the worker thread.
    pub fn spawn(cfg: Config, dim: usize) -> Coordinator {
        let (tx, rx) = sync_channel(cfg.queue.max(1));
        let join = std::thread::spawn(move || worker(cfg, dim, rx));
        Coordinator { tx, join: Some(join) }
    }

    /// Ingest one example (blocks under backpressure).
    pub fn ingest(&self, x: Vec<f64>) -> Result<IngestReply, String> {
        let (rtx, rrx) = sync_channel(1);
        self.tx.send(Command::Ingest(x, rtx)).map_err(|_| "coordinator down".to_string())?;
        rrx.recv().map_err(|_| "coordinator dropped reply".to_string())?
    }

    /// Project a point onto the current top-`r` components.
    pub fn project(&self, x: Vec<f64>, r: usize) -> Result<Vec<f64>, String> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Command::Project(x, r, rtx))
            .map_err(|_| "coordinator down".to_string())?;
        rrx.recv().map_err(|_| "coordinator dropped reply".to_string())?
    }

    /// Force an immediate drift measurement.
    pub fn measure_drift(&self) -> Result<DriftPoint, String> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Command::MeasureDrift(rtx))
            .map_err(|_| "coordinator down".to_string())?;
        rrx.recv().map_err(|_| "coordinator dropped reply".to_string())?
    }

    pub fn snapshot(&self) -> Result<Snapshot, String> {
        let (rtx, rrx) = sync_channel(1);
        self.tx.send(Command::Snapshot(rtx)).map_err(|_| "coordinator down".to_string())?;
        rrx.recv().map_err(|_| "coordinator dropped reply".to_string())
    }

    pub fn metrics(&self) -> Result<MetricsReport, String> {
        let (rtx, rrx) = sync_channel(1);
        self.tx.send(Command::Metrics(rtx)).map_err(|_| "coordinator down".to_string())?;
        rrx.recv().map_err(|_| "coordinator dropped reply".to_string())
    }

    /// Drain a whole stream source through the coordinator, returning
    /// the number of accepted examples.
    pub fn ingest_stream(&self, src: &mut dyn StreamSource) -> Result<usize, String> {
        let mut accepted = 0;
        while let Some(x) = src.next_example() {
            if self.ingest(x)?.accepted {
                accepted += 1;
            }
        }
        Ok(accepted)
    }

    /// Stop the worker and return final stats.
    pub fn shutdown(mut self) -> KpcaStats {
        let _ = self.tx.send(Command::Shutdown);
        self.join.take().map(|j| j.join().unwrap_or_default()).unwrap_or_default()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn build_kernel(cfg: &KernelConfig, seed: &Mat) -> Box<dyn Kernel> {
    match cfg {
        KernelConfig::Rbf { sigma } => Box::new(crate::kernels::Rbf { sigma: *sigma }),
        KernelConfig::RbfMedian => {
            let sigma = median_heuristic(seed, 500);
            Box::new(crate::kernels::Rbf { sigma })
        }
        KernelConfig::Linear => Box::new(crate::kernels::Linear),
        KernelConfig::Polynomial { degree, offset } => {
            Box::new(crate::kernels::Polynomial { degree: *degree, offset: *offset })
        }
        KernelConfig::Laplacian { sigma } => {
            Box::new(crate::kernels::Laplacian { sigma: *sigma })
        }
    }
}

fn build_engine(cfg: &EngineConfig) -> RoutedEngine {
    match cfg {
        EngineConfig::Native => RoutedEngine::native_only(),
        EngineConfig::Pjrt { dir, policy } => {
            match crate::runtime::Runtime::new(std::path::Path::new(dir)) {
                Ok(rt) => RoutedEngine::with_pjrt(
                    crate::runtime::PjrtRotate::new(std::sync::Arc::new(rt)),
                    policy.clone(),
                ),
                Err(e) => {
                    eprintln!("coordinator: pjrt unavailable ({e}); using native engine");
                    RoutedEngine::native_only()
                }
            }
        }
    }
}

fn worker(cfg: Config, dim: usize, rx: Receiver<Command>) -> KpcaStats {
    let engine = build_engine(&cfg.engine);
    let mut metrics = Metrics::default();
    let mut drift = DriftMonitor::new(cfg.drift_every);
    let mut seed_buf: Vec<f64> = Vec::new();
    let mut seeded = 0usize;
    // The state borrows the kernel; we intentionally `Box::leak` one
    // kernel per coordinator (long-lived singleton, a few bytes) to get
    // the `'static` lifetime the owning thread needs.
    let mut state: Option<IncrementalKpca<'static>> = None;
    let min_seed = if cfg.mean_adjust { cfg.seed_points.max(2) } else { cfg.seed_points.max(1) };

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Command::Ingest(x, reply) => {
                let t0 = Instant::now();
                if x.len() != dim {
                    metrics.errors += 1;
                    let _ = reply.send(Err(format!(
                        "dimension mismatch: got {}, want {dim}",
                        x.len()
                    )));
                    continue;
                }
                let result = if state.is_none() {
                    // Seeding phase: buffer until the batch init.
                    seed_buf.extend_from_slice(&x);
                    seeded += 1;
                    if seeded >= min_seed {
                        let seed = Mat::from_vec(seeded, dim, seed_buf.clone());
                        let k: &'static dyn Kernel =
                            Box::leak(build_kernel(&cfg.kernel, &seed));
                        match IncrementalKpca::from_batch(k, &seed, cfg.mean_adjust) {
                            Ok(s) => {
                                state = Some(s);
                                Ok(IngestReply { accepted: true, m: seeded, seeding: false })
                            }
                            Err(e) => {
                                metrics.errors += 1;
                                Err(e)
                            }
                        }
                    } else {
                        Ok(IngestReply { accepted: true, m: seeded, seeding: true })
                    }
                } else {
                    let st = state.as_mut().unwrap();
                    match st.push_with(&x, &engine) {
                        Ok(accepted) => {
                            if accepted {
                                metrics.accepted += 1;
                                drift.on_accept(st);
                            } else {
                                metrics.excluded += 1;
                            }
                            // Refresh the per-stream hot-path gauges
                            // (workspace + eigenbasis residency/growth).
                            metrics.updates = st.stats.updates as u64;
                            metrics.ws_bytes_resident = st.hot_path_bytes() as u64;
                            metrics.ws_reallocs = st.hot_path_reallocs();
                            Ok(IngestReply { accepted, m: st.len(), seeding: false })
                        }
                        Err(e) => {
                            metrics.errors += 1;
                            Err(e)
                        }
                    }
                };
                metrics.ingest_latency.record(t0.elapsed());
                let _ = reply.send(result);
            }
            Command::Project(x, r, reply) => {
                let t0 = Instant::now();
                let result = match (&state, x.len() == dim) {
                    (Some(st), true) => {
                        // The kernel reference lives inside the state.
                        Ok(st.project(st_kernel(st), &x, r))
                    }
                    (Some(_), false) => Err("dimension mismatch".to_string()),
                    (None, _) => Err("not initialized (still seeding)".to_string()),
                };
                metrics.project_latency.record(t0.elapsed());
                let _ = reply.send(result);
            }
            Command::MeasureDrift(reply) => {
                let result = match &state {
                    Some(st) => Ok(drift.measure(st)),
                    None => Err("not initialized".to_string()),
                };
                let _ = reply.send(result);
            }
            Command::Snapshot(reply) => {
                let snap = match &state {
                    Some(st) => Snapshot {
                        m: st.len(),
                        dim,
                        top_values: st.vals.iter().rev().take(10).copied().collect(),
                        stats: st.stats,
                        drift: drift.latest().copied(),
                        engine_calls: engine.counts(),
                    },
                    None => Snapshot {
                        m: seeded,
                        dim,
                        top_values: Vec::new(),
                        stats: KpcaStats::default(),
                        drift: None,
                        engine_calls: engine.counts(),
                    },
                };
                let _ = reply.send(snap);
            }
            Command::Metrics(reply) => {
                let _ = reply.send(metrics.report());
            }
            Command::Shutdown => break,
        }
    }
    state.map(|s| s.stats).unwrap_or_default()
}

/// Fetch the kernel a state was built over (stored by reference).
fn st_kernel<'a>(st: &'a IncrementalKpca<'_>) -> &'a dyn Kernel {
    st.kernel_ref()
}

/// Convenience: drift norms of a snapshot, if measured.
pub fn snapshot_drift(snap: &Snapshot) -> Option<Norms> {
    snap.drift.map(|d| d.norms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::data::SliceSource;

    fn config() -> Config {
        Config { seed_points: 6, drift_every: 4, ..Config::default() }
    }

    #[test]
    fn end_to_end_stream_session() {
        let ds = yeast_like(30, 1);
        let dim = ds.dim();
        let coord = Coordinator::spawn(config(), dim);
        let mut src = SliceSource::new(ds);
        let accepted = coord.ingest_stream(&mut src).unwrap();
        assert_eq!(accepted, 30);
        let snap = coord.snapshot().unwrap();
        assert_eq!(snap.m, 30);
        assert!(!snap.top_values.is_empty());
        assert!(snap.drift.is_some());
        assert!(snap.drift.unwrap().norms.frobenius < 1e-7);
        let report = coord.metrics().unwrap();
        assert_eq!(report.accepted as usize, 30 - 6); // post-seed accepts
        // Hot-path gauges are live: buffers resident, growth amortized
        // (far fewer growth events than rank-one updates performed).
        assert!(report.ws_bytes_resident > 0);
        assert!(report.reallocs_per_update < 1.0, "report {report}");
        let stats = coord.shutdown();
        assert_eq!(stats.accepted, 30);
    }

    #[test]
    fn projection_after_seeding() {
        let ds = yeast_like(20, 2);
        let dim = ds.dim();
        let coord = Coordinator::spawn(config(), dim);
        // Before seeding completes, projection errors cleanly.
        assert!(coord.project(vec![0.1; dim], 2).is_err());
        for i in 0..20 {
            coord.ingest(ds.x.row(i).to_vec()).unwrap();
        }
        let scores = coord.project(vec![0.3; dim], 3).unwrap();
        assert_eq!(scores.len(), 3);
        coord.shutdown();
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let coord = Coordinator::spawn(config(), 4);
        assert!(coord.ingest(vec![0.0; 3]).is_err());
        let report = coord.metrics().unwrap();
        assert_eq!(report.errors, 1);
        coord.shutdown();
    }

    #[test]
    fn explicit_drift_measurement() {
        let ds = yeast_like(12, 3);
        let coord = Coordinator::spawn(Config { seed_points: 6, ..Config::default() }, ds.dim());
        assert!(coord.measure_drift().is_err()); // not seeded yet
        for i in 0..12 {
            coord.ingest(ds.x.row(i).to_vec()).unwrap();
        }
        let d = coord.measure_drift().unwrap();
        assert_eq!(d.m, 12);
        assert!(d.norms.frobenius < 1e-8);
        coord.shutdown();
    }

    #[test]
    fn shutdown_idempotent_under_drop() {
        let coord = Coordinator::spawn(config(), 3);
        drop(coord); // must not hang or panic
    }
}
