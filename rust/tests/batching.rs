//! Batched-ingest equivalence suite: `ingest_many` and
//! `ingest_async`-then-`sync` must reach exactly the state sequential
//! `ingest` reaches (≤1e-10) across kernel families and batch shapes,
//! including batches that straddle the seeding boundary and batches
//! with mid-batch §5.1 exclusions / deflation-heavy duplicates — plus
//! the zero-realloc steady-state guarantee of the batched hot path,
//! and the blocked rank-b rotation: the fused strategy must match the
//! sequential one ≤1e-10 everywhere (deflation fallbacks included)
//! while dispatching strictly fewer engine back-rotation GEMMs.

mod common;

use common::oracle;
use inkpca::coordinator::{
    EngineConfig, KernelConfig, PoolConfig, ShardPool, StreamConfig, StreamHandle, StreamRouter,
};
use inkpca::data::synthetic::yeast_like;
use inkpca::data::Dataset;
use inkpca::kernels::{Kernel, Linear, Polynomial, Rbf};
use inkpca::kpca::{BatchRotation, IncrementalKpca};

fn cfg(kernel: KernelConfig, mean_adjust: bool) -> StreamConfig {
    StreamConfig { kernel, mean_adjust, seed_points: 6, ..StreamConfig::default() }
}

fn drive_sequential(router: &StreamRouter, h: &StreamHandle, ds: &Dataset) {
    for i in 0..ds.n() {
        router.ingest(h, ds.x.row(i).to_vec()).unwrap();
    }
}

fn drive_batched(router: &StreamRouter, h: &StreamHandle, ds: &Dataset, batch: usize) {
    let reply = router.ingest_all(h, ds.x.as_slice(), ds.dim(), batch).unwrap();
    assert_eq!(reply.seeded + reply.accepted + reply.excluded, ds.n());
    assert_eq!(reply.m, ds.n() - reply.excluded);
}

fn drive_async(router: &StreamRouter, h: &StreamHandle, ds: &Dataset) {
    for i in 0..ds.n() {
        router.ingest_async(h, ds.x.row(i).to_vec()).unwrap();
    }
    assert_eq!(router.sync(h).unwrap(), 0, "{}: async stream saw errors", h.id());
}

/// All three ingest shapes against one dataset/kernel/adjust mode; the
/// batched and async streams must match the sequential one ≤ 1e-10 on
/// eigenvalues and projection magnitudes.
fn assert_ingest_shapes_equivalent(kernel: KernelConfig, mean_adjust: bool, seed: u64) {
    let ds = oracle::std_stream(27, seed);
    let pool = ShardPool::spawn(PoolConfig {
        shards: 2,
        queue: 16,
        engine: EngineConfig::Native,
        ..PoolConfig::default()
    });
    let router = pool.router();
    let hs = router.open_stream("seq", ds.dim(), cfg(kernel.clone(), mean_adjust)).unwrap();
    let h5 = router.open_stream("b5", ds.dim(), cfg(kernel.clone(), mean_adjust)).unwrap();
    let h64 = router.open_stream("b64", ds.dim(), cfg(kernel.clone(), mean_adjust)).unwrap();
    let ha = router.open_stream("asy", ds.dim(), cfg(kernel.clone(), mean_adjust)).unwrap();
    drive_sequential(&router, &hs, &ds);
    drive_batched(&router, &h5, &ds, 5); // straddles the seeding boundary
    drive_batched(&router, &h64, &ds, 64); // whole stream in one command
    drive_async(&router, &ha, &ds);

    let reference = router.snapshot(&hs).unwrap();
    assert_eq!(reference.m, 27);
    let probe = vec![0.3; ds.dim()];
    let ref_proj = router.project(&hs, probe.clone(), 4).unwrap();
    for h in [&h5, &h64, &ha] {
        let snap = router.snapshot(h).unwrap();
        assert_eq!(snap.m, 27, "{:?} {}", kernel, h.id());
        for (got, want) in snap.top_values.iter().zip(&reference.top_values) {
            assert!(
                (got - want).abs() <= 1e-10,
                "{:?} {}: eigenvalue {got} vs {want}",
                kernel,
                h.id()
            );
        }
        let proj = router.project(h, probe.clone(), 4).unwrap();
        for (g, w) in proj.iter().zip(&ref_proj) {
            assert!(
                (g.abs() - w.abs()).abs() <= 1e-10,
                "{:?} {}: projection {g} vs {w}",
                kernel,
                h.id()
            );
        }
    }
    pool.shutdown();
}

#[test]
fn batched_equals_sequential_rbf_adjusted() {
    assert_ingest_shapes_equivalent(KernelConfig::Rbf { sigma: 1.2 }, true, 900);
}

#[test]
fn batched_equals_sequential_linear_unadjusted() {
    assert_ingest_shapes_equivalent(KernelConfig::Linear, false, 901);
}

#[test]
fn batched_equals_sequential_poly_adjusted() {
    assert_ingest_shapes_equivalent(
        KernelConfig::Polynomial { degree: 2, offset: 1.0 },
        true,
        902,
    );
}

/// Duplicate points make the adjusted kernel matrix singular — the
/// deflation path runs *inside* a batch and must stay ≤1e-10 equal to
/// the sequential run through the same points.
#[test]
fn deflation_heavy_batch_matches_sequential() {
    let ds = oracle::std_stream(12, 903);
    let dim = ds.dim();
    // points 6.. with two mid-batch duplicates of earlier rows.
    let mut tail: Vec<f64> = Vec::new();
    for i in 6..10 {
        tail.extend_from_slice(ds.x.row(i));
        tail.extend_from_slice(ds.x.row(i - 4)); // duplicate
    }
    let kern = Rbf { sigma: 1.0 };
    let seed = ds.x.submatrix(6, dim);
    let mut seq = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    for chunk in tail.chunks(dim) {
        seq.push(chunk).unwrap();
    }
    let mut bat = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    let out = bat.push_batch(&tail).unwrap();
    assert_eq!(out.accepted + out.excluded, 8);
    assert_eq!(seq.len(), bat.len());
    let diff = bat.reconstruct().max_abs_diff(&seq.reconstruct());
    assert!(diff < 1e-10, "deflation-heavy batch diff {diff}");
    // And the batched run still tracks the batch-recomputed ground
    // truth through the singular stretches.
    let drift = bat.reconstruct().max_abs_diff(&bat.batch_reference());
    assert!(drift < 1e-7, "drift {drift}");
}

/// Batch equivalence across kernel families at the library level, with
/// ragged batch sizes (1, 3, then the rest) against point-by-point.
#[test]
fn ragged_batches_match_sequential_across_kernels() {
    let kernels: Vec<Box<dyn Kernel>> = vec![
        Box::new(Rbf { sigma: 0.9 }),
        Box::new(Linear),
        Box::new(Polynomial { degree: 3, offset: 0.7 }),
    ];
    for (ki, kern) in kernels.iter().enumerate() {
        for &mean_adjust in &[false, true] {
            let ds = oracle::std_stream(22, 910 + ki as u64);
            let dim = ds.dim();
            let seed = ds.x.submatrix(5, dim);
            let flat = ds.x.as_slice();
            let mut seq = IncrementalKpca::from_batch(kern.as_ref(), &seed, mean_adjust).unwrap();
            for i in 5..ds.n() {
                seq.push(ds.x.row(i)).unwrap();
            }
            let mut bat = IncrementalKpca::from_batch(kern.as_ref(), &seed, mean_adjust).unwrap();
            bat.push_batch(&flat[5 * dim..6 * dim]).unwrap(); // b = 1
            bat.push_batch(&flat[6 * dim..9 * dim]).unwrap(); // b = 3
            bat.push_batch(&flat[9 * dim..22 * dim]).unwrap(); // b = 13
            assert_eq!(seq.len(), bat.len());
            let diff = bat.reconstruct().max_abs_diff(&seq.reconstruct());
            assert!(
                diff < 1e-10,
                "kernel {} adjust={mean_adjust}: diff {diff}",
                kern.name()
            );
        }
    }
}

/// Fused vs sequential back-rotation across kernel families × both
/// mean-adjust modes: identical eigensystems ≤1e-10, and — when the
/// kernel's spectrum leaves updates clean (`expect_amortization`) —
/// strictly fewer engine GEMMs (workspace-counted) on the fused side.
/// A rank-deficient kernel (linear in d=8 with n ≫ d) carries a
/// cluster of numerically repeated zero eigenvalues, so *every* update
/// correctly takes the deflation fallback: equivalence still holds,
/// amortization legitimately does not.
fn assert_rotation_strategies_equivalent(
    kern: &dyn Kernel,
    mean_adjust: bool,
    seed: u64,
    expect_amortization: bool,
) {
    let ds = oracle::std_stream(29, seed);
    let dim = ds.dim();
    let seed_mat = ds.x.submatrix(5, dim);
    let flat = ds.x.as_slice();
    let mut runs = Vec::new();
    for rot in [BatchRotation::Fused, BatchRotation::Sequential] {
        let mut inc = IncrementalKpca::from_batch(kern, &seed_mat, mean_adjust).unwrap();
        inc.batch_rotation = Some(rot);
        let mut i = 5;
        while i < ds.n() {
            let end = (i + 6).min(ds.n());
            inc.push_batch(&flat[i * dim..end * dim]).unwrap();
            i = end;
        }
        assert!(
            !inc.workspace().pending_rotation(),
            "no pending rotation may survive a batch boundary"
        );
        runs.push(inc);
    }
    let (fus, seq) = (&runs[0], &runs[1]);
    assert_eq!(fus.len(), seq.len());
    for (a, b) in fus.vals.iter().zip(&seq.vals) {
        assert!(
            (a - b).abs() <= 1e-10,
            "{} adjust={mean_adjust}: eigenvalue {a} vs {b}",
            kern.name()
        );
    }
    let diff = fus.reconstruct().max_abs_diff(&seq.reconstruct());
    assert!(
        diff <= 1e-10,
        "{} adjust={mean_adjust}: fused vs sequential reconstruction diff {diff}",
        kern.name()
    );
    // The fused run must also still track the batch ground truth.
    let drift = fus.reconstruct().max_abs_diff(&fus.batch_reference());
    assert!(drift < 1e-7, "{} adjust={mean_adjust}: drift {drift}", kern.name());
    if expect_amortization {
        assert!(
            fus.engine_gemms() < seq.engine_gemms(),
            "{} adjust={mean_adjust}: fused {} vs sequential {} engine GEMMs",
            kern.name(),
            fus.engine_gemms(),
            seq.engine_gemms()
        );
        assert!(fus.workspace().fused_updates() > 0);
    } else {
        // Every update fell back — never more GEMMs than sequential.
        assert!(fus.engine_gemms() <= seq.engine_gemms());
    }
}

#[test]
fn fused_rotation_matches_sequential_rbf() {
    assert_rotation_strategies_equivalent(&Rbf { sigma: 1.2 }, true, 930, true);
    assert_rotation_strategies_equivalent(&Rbf { sigma: 0.8 }, false, 931, true);
}

#[test]
fn fused_rotation_matches_sequential_linear() {
    // Linear on d=8 with 29 points: the Gram is rank-deficient, its
    // zero-eigenvalue cluster keeps deflation live, and the fused path
    // must *survive* by falling back — equivalence without
    // amortization.
    assert_rotation_strategies_equivalent(&Linear, true, 932, false);
    assert_rotation_strategies_equivalent(&Linear, false, 933, false);
}

#[test]
fn fused_rotation_matches_sequential_poly() {
    assert_rotation_strategies_equivalent(&Polynomial { degree: 2, offset: 1.0 }, true, 934, true);
    assert_rotation_strategies_equivalent(&Polynomial { degree: 3, offset: 0.5 }, false, 935, true);
}

/// Duplicate points inside a fused batch force the mid-batch
/// `Sequential` fallback (repeated eigenvalues → deflation Givens); the
/// fused run must still match the forced-sequential run ≤1e-10 and
/// record the fallbacks it took.
#[test]
fn fused_deflation_heavy_batch_falls_back_and_matches() {
    let ds = oracle::std_stream(12, 936);
    let dim = ds.dim();
    let mut tail: Vec<f64> = Vec::new();
    for i in 6..10 {
        tail.extend_from_slice(ds.x.row(i));
        tail.extend_from_slice(ds.x.row(i - 4)); // duplicate of a retained row
    }
    let kern = Rbf { sigma: 1.0 };
    let seed = ds.x.submatrix(6, dim);
    let mut fus = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    fus.batch_rotation = Some(BatchRotation::Fused);
    let mut seq = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    seq.batch_rotation = Some(BatchRotation::Sequential);
    let of = fus.push_batch(&tail).unwrap();
    let os = seq.push_batch(&tail).unwrap();
    assert_eq!(of.accepted, os.accepted);
    assert_eq!(of.excluded, os.excluded);
    assert!(
        fus.workspace().fused_fallbacks() > 0,
        "duplicates must force the sequential fallback mid-batch"
    );
    assert!(
        fus.workspace().fused_updates() > 0,
        "clean updates in the same batch must still fuse"
    );
    let diff = fus.reconstruct().max_abs_diff(&seq.reconstruct());
    assert!(diff < 1e-10, "deflation-heavy fused batch diff {diff}");
    let drift = fus.reconstruct().max_abs_diff(&fus.batch_reference());
    assert!(drift < 1e-7, "drift {drift}");
}

/// Mid-batch §5.1 exclusion under the fused strategy: the excluded
/// point triggers no updates (the pending rotation from the points
/// before it is simply carried over, no flush), and the batch still
/// matches the sequential run.
#[test]
fn fused_batch_with_mid_batch_exclusion_matches() {
    let ds = yeast_like(10, 937);
    let kern = Linear;
    let dim = ds.dim();
    let seed = ds.x.submatrix(6, dim);
    // The mean of the retained set *as it will be when the point is
    // evaluated* — seed plus row 6, already applied earlier in the same
    // batch. Under the linear kernel that point has centered diagonal
    // v₀ = 0, so the §5.1 exclusion fires mid-batch, with a rotation
    // product already pending on the fused side.
    let mean: Vec<f64> =
        (0..dim).map(|j| (0..7).map(|i| ds.x[(i, j)]).sum::<f64>() / 7.0).collect();
    let mut batch = Vec::new();
    batch.extend_from_slice(ds.x.row(6));
    batch.extend_from_slice(&mean); // mean of rows 0..=6 → v₀ = 0 → excluded
    batch.extend_from_slice(ds.x.row(7));
    batch.extend_from_slice(ds.x.row(8));

    let mut fus = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    fus.batch_rotation = Some(BatchRotation::Fused);
    let out = fus.push_batch(&batch).unwrap();
    assert_eq!(out.excluded, 1);
    assert_eq!(fus.last_batch_mask(), &[true, false, true, true]);

    let mut seq = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    seq.batch_rotation = Some(BatchRotation::Sequential);
    seq.push_batch(&batch).unwrap();
    let diff = fus.reconstruct().max_abs_diff(&seq.reconstruct());
    assert!(diff < 1e-10, "mid-batch exclusion fused diff {diff}");
}

/// Through the router: a fused stream and a forced-sequential stream
/// fed identical seeding-straddling batches agree ≤1e-10, and the
/// pool's workspace-counted GEMM gauges show the amortization.
#[test]
fn router_fused_stream_matches_sequential_stream() {
    let ds = oracle::std_stream(30, 938);
    let pool = ShardPool::spawn(PoolConfig {
        shards: 2,
        queue: 16,
        engine: EngineConfig::Native,
        ..PoolConfig::default()
    });
    let router = pool.router();
    let mk = |rot| StreamConfig {
        kernel: KernelConfig::Rbf { sigma: 1.1 },
        mean_adjust: true,
        seed_points: 6,
        batch_rotation: Some(rot),
        expected_m: 32,
        expected_batch: 8,
        ..StreamConfig::default()
    };
    let hf = router.open_stream("fused", ds.dim(), mk(BatchRotation::Fused)).unwrap();
    let hs = router.open_stream("seqrot", ds.dim(), mk(BatchRotation::Sequential)).unwrap();
    // Batch 8 with seed 6: the first command straddles the seeding
    // boundary (6 seeded + 2 batched) — the fused path starts mid-batch
    // on a freshly built eigensystem.
    drive_batched(&router, &hf, &ds, 8);
    drive_batched(&router, &hs, &ds, 8);
    let sf = router.snapshot(&hf).unwrap();
    let ss = router.snapshot(&hs).unwrap();
    assert_eq!(sf.m, 30);
    assert_eq!(ss.m, 30);
    for (a, b) in sf.top_values.iter().zip(&ss.top_values) {
        assert!((a - b).abs() <= 1e-10, "eigenvalue {a} vs {b}");
    }
    let probe = vec![0.4; ds.dim()];
    let pf = router.project(&hf, probe.clone(), 4).unwrap();
    let ps = router.project(&hs, probe, 4).unwrap();
    for (g, w) in pf.iter().zip(&ps) {
        assert!((g.abs() - w.abs()).abs() <= 1e-10, "projection {g} vs {w}");
    }
    // Workspace-counted GEMM gauges: per-stream and in the pool rollup.
    let mf = router.metrics(&hf).unwrap();
    let ms = router.metrics(&hs).unwrap();
    assert!(
        mf.engine_gemms < ms.engine_gemms,
        "fused stream {} vs sequential stream {} engine GEMMs",
        mf.engine_gemms,
        ms.engine_gemms
    );
    // Sequential adjusted mode pays up to 4 GEMMs per accepted point
    // (expansion + final updates always dispatch two; the two
    // re-centering updates can skip only in degenerate cases).
    assert!(ms.engine_gemms >= 2 * ms.accepted && ms.engine_gemms <= 4 * ms.accepted);
    let snap = router.pool_snapshot().unwrap();
    assert_eq!(snap.ws_engine_gemms, mf.engine_gemms + ms.engine_gemms);
    let gf = snap.per_stream.iter().find(|g| g.stream == "fused").unwrap();
    assert_eq!(gf.engine_gemms, mf.engine_gemms);
    // Reserve-at-open (`expected_m`/`expected_batch`): both streams
    // were pre-sized when their eigensystems were built, so the whole
    // streamed run — batched kernel blocks, fused rotation scratch,
    // eigenbasis growth — must leave the growth gauge at exactly zero.
    // If the worker's reserve call regresses, these counters go
    // positive (buffers grow across the first batches).
    assert_eq!(mf.ws_reallocs, 0, "reserve-at-open must pre-size the fused stream");
    assert_eq!(ms.ws_reallocs, 0, "reserve-at-open must pre-size the sequential stream");
    pool.shutdown();
}

/// The zero-realloc steady-state guarantee for the batched path: with
/// the stream pre-sized ([`IncrementalKpca::reserve`]), a batched run
/// must leave every tracked hot-path counter untouched — the workspace
/// and eigenbasis (as in the sequential guarantee) *and* the batch
/// scratch (kernel blocks, row norms, assembly buffers).
#[test]
fn batched_steady_state_is_zero_realloc() {
    let ds = oracle::std_stream(46, 920);
    let dim = ds.dim();
    let kern = Rbf { sigma: 1.1 };
    let seed = ds.x.submatrix(6, dim);
    let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    inc.reserve(48, 8);
    let ws0 = inc.hot_path_reallocs();
    let batch0 = inc.batch_reallocs();
    let flat = ds.x.as_slice();
    let mut i = 6;
    while i < ds.n() {
        let end = (i + 8).min(ds.n());
        inc.push_batch(&flat[i * dim..end * dim]).unwrap();
        i = end;
    }
    assert_eq!(inc.len(), 46);
    assert_eq!(inc.hot_path_reallocs(), ws0, "workspace/basis allocated in steady state");
    assert_eq!(inc.batch_reallocs(), batch0, "batch scratch allocated in steady state");
    // The same stream keeps running batch-silent on further batches of
    // the reserved size.
    let extra = yeast_like(8, 921);
    let mut tail = Vec::new();
    for i in 0..2 {
        tail.extend_from_slice(extra.x.row(i));
    }
    inc.push_batch(&tail).unwrap();
    assert_eq!(inc.batch_reallocs(), batch0);
}
