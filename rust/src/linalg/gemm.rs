//! Blocked, cache-aware, parallel matrix multiplication and the small
//! BLAS-2 kernels the rest of the crate needs — all expressed over
//! [`MatView`]/[`MatViewMut`] so the streaming hot path can run into
//! caller-owned buffers without allocating. The allocating entry points
//! (`matmul`, `gemv`, …) are thin wrappers and accept anything
//! convertible to a view (`&Mat`, `MatView`, `&rankone::EigenBasis`).
//! The same products can also be routed to an AOT PJRT executable via
//! `runtime`/`coordinator::router`.

use super::matrix::Mat;
use super::view::{MatView, MatViewMut};
use crate::util::par;

/// Row-panel height used by the blocked kernel. Chosen so that an
/// `MC × KC` panel of `a` plus a `KC × cols` strip of `b` stay in L2.
const MC: usize = 64;
/// Depth blocking factor.
const KC: usize = 256;
/// Parallelism threshold: below this many flops, threads cost more than
/// they save.
const PAR_FLOPS: usize = 1 << 20;

/// `C = A · B` into a caller-owned view (zeroed first). The blocked,
/// register-tiled kernel runs in parallel over `MC`-row panels of `C`
/// when the flop count warrants it; all three operands may be strided.
pub fn matmul_into(a: MatView<'_>, b: MatView<'_>, out: &mut MatViewMut<'_>) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(out.rows(), a.rows(), "matmul out rows mismatch");
    assert_eq!(out.cols(), b.cols(), "matmul out cols mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    out.fill_zero();
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let (sa, sb, sc) = (a.stride(), b.stride(), out.stride());
    let a_data = a.raw();
    let b_data = b.raw();
    if 2 * m * k * n < PAR_FLOPS {
        let c_data = out.raw_mut();
        for kk in (0..k).step_by(KC) {
            let kend = (kk + KC).min(k);
            gemm_panel(a_data, sa, b_data, sb, c_data, sc, 0, m, n, kk, kend);
        }
    } else {
        par::par_chunks_mut(out.raw_mut(), MC * sc, |blk, c_panel| {
            let i0 = blk * MC;
            if i0 >= m {
                return; // capacity rows beyond the viewed window
            }
            let i1 = (i0 + MC).min(m);
            for kk in (0..k).step_by(KC) {
                let kend = (kk + KC).min(k);
                gemm_panel(a_data, sa, b_data, sb, c_panel, sc, i0, i1, n, kk, kend);
            }
        });
    }
}

/// `C = A · B`.
pub fn matmul<'a, 'b>(a: impl Into<MatView<'a>>, b: impl Into<MatView<'b>>) -> Mat {
    let (a, b) = (a.into(), b.into());
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let mut c = Mat::zeros(a.rows(), b.cols());
    let mut cv = c.view_mut();
    matmul_into(a, b, &mut cv);
    c
}

/// Inner kernel: accumulate rows `i0..i1` of `C` over the `kk..kend`
/// depth slice, with 4-row register blocking — each `brow` load feeds
/// four FMAs, quadrupling arithmetic intensity vs the plain axpy form
/// (the win measured in EXPERIMENTS.md §Perf). `c_panel` starts at row
/// `i0`; `sa`/`sb`/`sc` are the row strides of the three operands.
#[allow(clippy::too_many_arguments)]
#[inline]
fn gemm_panel(
    a_data: &[f64],
    sa: usize,
    b_data: &[f64],
    sb: usize,
    c_panel: &mut [f64],
    sc: usize,
    i0: usize,
    i1: usize,
    n: usize,
    kk: usize,
    kend: usize,
) {
    let mut i = i0;
    while i + 4 <= i1 {
        // Split the 4 destination rows without aliasing.
        let base = (i - i0) * sc;
        let (r0, rest) = c_panel[base..].split_at_mut(sc);
        let (r1, rest) = rest.split_at_mut(sc);
        let (r2, rest) = rest.split_at_mut(sc);
        let r0 = &mut r0[..n];
        let r1 = &mut r1[..n];
        let r2 = &mut r2[..n];
        let r3 = &mut rest[..n];
        for p in kk..kend {
            let a0 = a_data[i * sa + p];
            let a1 = a_data[(i + 1) * sa + p];
            let a2 = a_data[(i + 2) * sa + p];
            let a3 = a_data[(i + 3) * sa + p];
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue;
            }
            let brow = &b_data[p * sb..p * sb + n];
            for j in 0..n {
                let bj = brow[j];
                r0[j] += a0 * bj;
                r1[j] += a1 * bj;
                r2[j] += a2 * bj;
                r3[j] += a3 * bj;
            }
        }
        i += 4;
    }
    while i < i1 {
        let base = (i - i0) * sc;
        let crow = &mut c_panel[base..base + n];
        for p in kk..kend {
            let aip = a_data[i * sa + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b_data[p * sb..p * sb + n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
        i += 1;
    }
}

/// `C = A · Bᵀ` into a caller-owned view — both row-major, so this is
/// the dot-product-friendly orientation (no transpose materialized).
pub fn matmul_nt_into(a: MatView<'_>, b: MatView<'_>, out: &mut MatViewMut<'_>) {
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    assert_eq!(out.rows(), a.rows(), "matmul_nt out rows mismatch");
    assert_eq!(out.cols(), b.rows(), "matmul_nt out cols mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    out.fill_zero();
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let sc = out.stride();
    let do_row = |i: usize, crow: &mut [f64]| {
        let arow = a.row(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut s = 0.0;
            for p in 0..k {
                s += arow[p] * brow[p];
            }
            crow[j] = s;
        }
    };
    if 2 * m * k * n < PAR_FLOPS {
        let c_data = out.raw_mut();
        for i in 0..m {
            do_row(i, &mut c_data[i * sc..i * sc + n]);
        }
    } else {
        par::par_chunks_mut(out.raw_mut(), sc, |i, crow| {
            if i < m {
                do_row(i, &mut crow[..n]);
            }
        });
    }
}

/// `C = A · Bᵀ` without materializing the transpose.
pub fn matmul_nt<'a, 'b>(a: impl Into<MatView<'a>>, b: impl Into<MatView<'b>>) -> Mat {
    let (a, b) = (a.into(), b.into());
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    let mut c = Mat::zeros(a.rows(), b.rows());
    let mut cv = c.view_mut();
    matmul_nt_into(a, b, &mut cv);
    c
}

/// `C = Aᵀ · B` into a caller-owned view. Small problems accumulate
/// rank-one outer products row by row (cache-friendly for row-major
/// operands); above the flop threshold the accumulation parallelizes
/// over disjoint output rows (each owning one strided column of `A`).
pub fn matmul_tn_into(a: MatView<'_>, b: MatView<'_>, out: &mut MatViewMut<'_>) {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
    assert_eq!(out.rows(), a.cols(), "matmul_tn out rows mismatch");
    assert_eq!(out.cols(), b.cols(), "matmul_tn out cols mismatch");
    let (m, r, n) = (a.rows(), a.cols(), b.cols());
    out.fill_zero();
    if m == 0 || r == 0 || n == 0 {
        return;
    }
    let sc = out.stride();
    let (sa, sb) = (a.stride(), b.stride());
    let a_data = a.raw();
    let b_data = b.raw();
    if 2 * m * r * n < PAR_FLOPS {
        let c_data = out.raw_mut();
        for p in 0..m {
            let arow = a.row(p);
            let brow = b.row(p);
            for (i, &aip) in arow.iter().enumerate() {
                if aip == 0.0 {
                    continue;
                }
                let crow = &mut c_data[i * sc..i * sc + n];
                for j in 0..n {
                    crow[j] += aip * brow[j];
                }
            }
        }
    } else {
        par::par_chunks_mut(out.raw_mut(), sc, |i, crow| {
            if i >= r {
                return;
            }
            let crow = &mut crow[..n];
            for p in 0..m {
                let aip = a_data[p * sa + i];
                if aip == 0.0 {
                    continue;
                }
                let brow = &b_data[p * sb..p * sb + n];
                for j in 0..n {
                    crow[j] += aip * brow[j];
                }
            }
        });
    }
}

/// `T = Aᵀ` into a caller-owned view.
pub fn transpose_into(a: MatView<'_>, out: &mut MatViewMut<'_>) {
    assert_eq!(out.rows(), a.cols(), "transpose out rows mismatch");
    assert_eq!(out.cols(), a.rows(), "transpose out cols mismatch");
    for i in 0..a.rows() {
        let arow = a.row(i);
        for (j, &v) in arow.iter().enumerate() {
            out[(j, i)] = v;
        }
    }
}

/// `y = A · x` into a caller-owned slice.
pub fn gemv_into(a: MatView<'_>, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.cols(), x.len(), "gemv shape mismatch");
    assert_eq!(a.rows(), y.len(), "gemv out length mismatch");
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = super::matrix::dot(a.row(i), x);
    }
}

/// `y = A · x`.
pub fn gemv<'a>(a: impl Into<MatView<'a>>, x: &[f64]) -> Vec<f64> {
    let a = a.into();
    let mut y = vec![0.0; a.rows()];
    gemv_into(a, x, &mut y);
    y
}

/// `y = Aᵀ · x` into a caller-owned slice.
pub fn gemv_t_into(a: MatView<'_>, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.rows(), x.len(), "gemv_t shape mismatch");
    assert_eq!(a.cols(), y.len(), "gemv_t out length mismatch");
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        for (j, yj) in y.iter_mut().enumerate() {
            *yj += xi * row[j];
        }
    }
}

/// `y = Aᵀ · x`.
pub fn gemv_t<'a>(a: impl Into<MatView<'a>>, x: &[f64]) -> Vec<f64> {
    let a = a.into();
    let mut y = vec![0.0; a.cols()];
    gemv_t_into(a, x, &mut y);
    y
}

/// Gram matrix `A · Aᵀ` (symmetric; computes the upper triangle once).
pub fn syrk(a: &Mat) -> Mat {
    let (m, k) = (a.rows(), a.cols());
    let mut c = Mat::zeros(m, m);
    let a_data = a.as_slice();
    let upper_row = |i: usize| -> Vec<f64> {
        let ai = &a_data[i * k..(i + 1) * k];
        (i..m)
            .map(|j| {
                let aj = &a_data[j * k..(j + 1) * k];
                super::matrix::dot(ai, aj)
            })
            .collect()
    };
    let results: Vec<Vec<f64>> = if 2 * m * m * k >= PAR_FLOPS {
        par::par_map(m, 1, upper_row)
    } else {
        (0..m).map(upper_row).collect()
    };
    for (i, rowvals) in results.into_iter().enumerate() {
        for (off, v) in rowvals.into_iter().enumerate() {
            let j = i + off;
            c[(i, j)] = v;
            c[(j, i)] = v;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|p| a[(i, p)] * b[(p, j)]).sum()
        })
    }

    #[test]
    fn matmul_matches_naive_small() {
        let a = Mat::from_fn(5, 7, |i, j| (i as f64 - j as f64) * 0.3);
        let b = Mat::from_fn(7, 4, |i, j| (i * j) as f64 * 0.1 + 1.0);
        let c = matmul(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-12);
    }

    #[test]
    fn matmul_matches_naive_blocked_sizes() {
        // Exercise the KC blocking boundary and parallel path.
        let a = Mat::from_fn(70, 300, |i, j| ((i * 7 + j * 13) % 11) as f64 - 5.0);
        let b = Mat::from_fn(300, 65, |i, j| ((i * 3 + j * 17) % 13) as f64 * 0.25);
        let c = matmul(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-9);
    }

    #[test]
    fn matmul_into_strided_out_matches() {
        // The output lives in a wider capacity buffer (stride > cols),
        // exactly how the workspace's rotated panel is laid out.
        let a = Mat::from_fn(9, 6, |i, j| ((i * 5 + j) % 7) as f64 - 3.0);
        let b = Mat::from_fn(6, 5, |i, j| ((i + 2 * j) % 5) as f64 * 0.5);
        let stride = 8;
        let mut buf = vec![f64::NAN; 12 * stride];
        {
            let mut out = MatViewMut::new(&mut buf, 9, 5, stride);
            matmul_into(a.view(), b.view(), &mut out);
        }
        let expect = naive(&a, &b);
        for i in 0..9 {
            for j in 0..5 {
                assert!((buf[i * stride + j] - expect[(i, j)]).abs() < 1e-12);
            }
        }
        // Gap columns untouched.
        assert!(buf[5].is_nan());
    }

    #[test]
    fn matmul_strided_inputs_match() {
        // a and b viewed as windows of wider buffers.
        let full_a = Mat::from_fn(4, 9, |i, j| (i * 9 + j) as f64 * 0.1);
        let full_b = Mat::from_fn(3, 7, |i, j| (i * 7 + j) as f64 * 0.2 - 1.0);
        let av = MatView::new(full_a.as_slice(), 4, 3, 9);
        let bv = MatView::new(full_b.as_slice(), 3, 4, 7);
        let c = matmul(av, bv);
        let a_win = av.to_mat();
        let b_win = bv.to_mat();
        assert!(c.max_abs_diff(&naive(&a_win, &b_win)) < 1e-12);
    }

    #[test]
    fn matmul_nt_matches() {
        let a = Mat::from_fn(6, 9, |i, j| (i + j) as f64 * 0.5);
        let b = Mat::from_fn(8, 9, |i, j| i as f64 * 1.5 - j as f64);
        let c = matmul_nt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn matmul_tn_matches() {
        let a = Mat::from_fn(7, 4, |i, j| ((i * 3 + j) as f64).sin());
        let b = Mat::from_fn(7, 5, |i, j| ((i + 2 * j) as f64).cos());
        let mut c = Mat::zeros(4, 5);
        {
            let mut cv = c.view_mut();
            matmul_tn_into(a.view(), b.view(), &mut cv);
        }
        let expect = matmul(&a.transpose(), &b);
        assert!(c.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn transpose_into_matches() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f64);
        let mut t = Mat::zeros(5, 3);
        {
            let mut tv = t.view_mut();
            transpose_into(a.view(), &mut tv);
        }
        assert!(t.max_abs_diff(&a.transpose()) < 1e-15);
    }

    #[test]
    fn gemv_matches() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let x = vec![1.0, -1.0, 2.0];
        let y = gemv(&a, &x);
        for i in 0..4 {
            let expect: f64 = (0..3).map(|j| a[(i, j)] * x[j]).sum();
            assert!((y[i] - expect).abs() < 1e-13);
        }
    }

    #[test]
    fn gemv_t_matches() {
        let a = Mat::from_fn(4, 3, |i, j| ((i * 3 + j) as f64).sin());
        let x = vec![0.5, 1.5, -2.0, 3.0];
        let y = gemv_t(&a, &x);
        let yt = gemv(&a.transpose(), &x);
        for (u, v) in y.iter().zip(yt.iter()) {
            assert!((u - v).abs() < 1e-13);
        }
    }

    #[test]
    fn syrk_matches_matmul() {
        let a = Mat::from_fn(10, 6, |i, j| ((i + 2 * j) as f64).cos());
        let c = syrk(&a);
        let c2 = matmul_nt(&a, &a);
        assert!(c.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn empty_shapes() {
        let a = Mat::zeros(0, 3);
        let b = Mat::zeros(3, 2);
        let c = matmul(&a, &b);
        assert_eq!(c.rows(), 0);
        assert_eq!(c.cols(), 2);
    }
}
