//! The Nyström method (§2.4) and the paper's §4 contribution: the first
//! incremental algorithm for the full Nyström approximation, built on
//! the incremental eigendecomposition of `K_{m,m}` plus the rescaling of
//! eq. (7). Also includes a Rudi-et-al.-2015-style incremental-Cholesky
//! variant as a comparison baseline.

pub mod batch;
pub mod cholesky_inc;
pub mod incremental;

pub use batch::BatchNystrom;
pub use cholesky_inc::CholeskyNystrom;
pub use incremental::IncrementalNystrom;
