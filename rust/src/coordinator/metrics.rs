//! Latency/throughput metrics for the streaming coordinator, split into
//! two altitudes:
//!
//! - **per-stream** ([`Metrics`]/[`MetricsReport`], plus the compact
//!   [`StreamGauges`]): latency histograms, accept/exclude/error
//!   counters and the hot-path allocation gauges
//!   (`ws_bytes_resident`, `reallocs_per_update`), one instance per
//!   stream entry in a shard;
//! - **pool-level** ([`PoolSnapshot`]): rollups across every shard and
//!   stream — total resident bytes, merged ingest/project latency
//!   histograms ([`LatencyHistogram::merge`]), aggregated engine
//!   dispatch counts — with the per-stream gauges attached for
//!   attribution.
//!
//! The histogram is a fixed log-spaced array (HDR-style): recording and
//! merging never allocate.

use std::time::{Duration, Instant};

/// Log-spaced histogram from 1 µs to ~17 s (2× per bucket).
const BUCKETS: usize = 25;
const BASE_NS: f64 = 1_000.0;

#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: [u64; BUCKETS],
    total: u64,
    sum_ns: f64,
    max_ns: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: [0; BUCKETS], total: 0, sum_ns: 0.0, max_ns: 0.0 }
    }
}

impl LatencyHistogram {
    fn bucket(ns: f64) -> usize {
        if ns <= BASE_NS {
            return 0;
        }
        let b = (ns / BASE_NS).log2().floor() as usize;
        b.min(BUCKETS - 1)
    }

    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos() as f64;
        self.counts[Self::bucket(ns)] += 1;
        self.total += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    /// Upper-bound estimate of percentile `p` in nanoseconds.
    pub fn percentile_ns(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                if b == BUCKETS - 1 {
                    // The clamped last bucket has no finite upper edge
                    // — every sample beyond the 2^25-µs ladder lands
                    // here, so the observed maximum is the only honest
                    // bound (the old bucket-edge answer under-reported
                    // any tail beyond ~33 s).
                    return self.max_ns;
                }
                // Upper edge of bucket b, tightened by the observed
                // max (no sample can exceed it).
                let edge = BASE_NS * (1u64 << (b + 1)) as f64;
                return edge.min(self.max_ns);
            }
        }
        self.max_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns / self.total as f64
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Fold another histogram into this one (bucket-wise; exact for
    /// counts/mean/max, and percentiles stay upper bounds) — how the
    /// pool rolls per-shard latency up into one distribution.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Aggregate coordinator metrics.
#[derive(Debug)]
pub struct Metrics {
    pub ingest_latency: LatencyHistogram,
    pub project_latency: LatencyHistogram,
    pub accepted: u64,
    pub excluded: u64,
    pub errors: u64,
    /// Subset of `errors` raised by fire-and-forget (`ingest_async`)
    /// commands — deferred rather than replied, surfaced by `sync`.
    pub async_errors: u64,
    /// Rank-one updates performed by the stream's eigensystem.
    pub updates: u64,
    /// Landmarks evicted by the bounded-memory down-date path (0 when
    /// the stream runs unbounded).
    pub evictions: u64,
    /// Approximation-sufficiency gauge: the share of the retained
    /// spectrum in the smallest positive eigenvalue, refreshed after
    /// each ingest. Small values mean the landmark set is sufficient —
    /// the signal the eviction policy keys off.
    pub sufficiency_gap: f64,
    /// Max projection divergence between the exact and sketched engine
    /// since the last snapshot publish — `Some` only on shadow-tier
    /// streams (see `coordinator::engine`); refreshed after each
    /// ingest.
    pub divergence: Option<f64>,
    /// Bytes resident in the stream's hot-path buffers (update
    /// workspace + eigenvector storage + batched-ingest scratch);
    /// refreshed after each ingest.
    pub ws_bytes_resident: u64,
    /// Cumulative buffer-growth events on the hot path — flat in steady
    /// state, stepping only on capacity doublings as the stream grows.
    pub ws_reallocs: u64,
    /// `U`-sized back-rotation GEMMs dispatched by the stream's update
    /// workspace (one per sequential rank-one update, one per
    /// blocked-batch flush) — the amortization gauge of the fused
    /// rank-b path.
    pub engine_gemms: u64,
    /// Projections served through the worker queue (`Project` RPCs).
    /// The lock-free counterpart — snapshot-path reads — lives in the
    /// stream's `SnapshotCell` and is reported next to this one; a
    /// healthy read-heavy deployment shows this flat while
    /// `snapshot_reads` grows.
    pub worker_reads: u64,
    /// Checkpoints of this stream written successfully.
    pub checkpoints: u64,
    /// Write-ahead log records appended for this stream.
    pub wal_appends: u64,
    /// Bytes those appends framed into the log.
    pub wal_bytes: u64,
    /// Failed log appends (the stream stayed live in memory — WAL
    /// failures degrade, they never take the write path down). A
    /// nonzero value means the log has gaps: recovery replays what was
    /// captured and the monotonic sequence numbers keep the rest
    /// unambiguous.
    pub wal_errors: u64,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            ingest_latency: LatencyHistogram::default(),
            project_latency: LatencyHistogram::default(),
            accepted: 0,
            excluded: 0,
            errors: 0,
            async_errors: 0,
            updates: 0,
            evictions: 0,
            sufficiency_gap: 0.0,
            divergence: None,
            ws_bytes_resident: 0,
            ws_reallocs: 0,
            engine_gemms: 0,
            worker_reads: 0,
            checkpoints: 0,
            wal_appends: 0,
            wal_bytes: 0,
            wal_errors: 0,
            started: Instant::now(),
        }
    }
}

impl Metrics {
    /// Growth events per rank-one update — the steady-state allocation
    /// gauge (≈0 once warm). Single definition shared by the per-stream
    /// report and the pool-snapshot gauges.
    pub fn reallocs_per_update(&self) -> f64 {
        self.ws_reallocs as f64 / self.updates.max(1) as f64
    }

    pub fn report(&self) -> MetricsReport {
        let elapsed = self.started.elapsed().as_secs_f64().max(1e-9);
        MetricsReport {
            accepted: self.accepted,
            excluded: self.excluded,
            errors: self.errors,
            async_errors: self.async_errors,
            uptime_s: elapsed,
            throughput_per_s: self.accepted as f64 / elapsed,
            ingest_p50_us: self.ingest_latency.percentile_ns(0.50) / 1e3,
            ingest_p99_us: self.ingest_latency.percentile_ns(0.99) / 1e3,
            ingest_mean_us: self.ingest_latency.mean_ns() / 1e3,
            project_mean_us: self.project_latency.mean_ns() / 1e3,
            evictions: self.evictions,
            sufficiency_gap: self.sufficiency_gap,
            divergence: self.divergence,
            ws_bytes_resident: self.ws_bytes_resident,
            ws_reallocs: self.ws_reallocs,
            reallocs_per_update: self.reallocs_per_update(),
            engine_gemms: self.engine_gemms,
            worker_reads: self.worker_reads,
            checkpoints: self.checkpoints,
            wal_appends: self.wal_appends,
            wal_bytes: self.wal_bytes,
            wal_errors: self.wal_errors,
            // Snapshot-cell fields are filled in by the stream entry
            // (the cell lives outside `Metrics`).
            snapshot_epoch: 0,
            snapshot_reads: 0,
            points_since_publish: 0,
        }
    }
}

/// Snapshot handed to callers (printable one-liner in examples/CLI).
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsReport {
    pub accepted: u64,
    pub excluded: u64,
    pub errors: u64,
    /// Deferred fire-and-forget failures (subset of `errors`).
    pub async_errors: u64,
    pub uptime_s: f64,
    pub throughput_per_s: f64,
    pub ingest_p50_us: f64,
    pub ingest_p99_us: f64,
    pub ingest_mean_us: f64,
    pub project_mean_us: f64,
    /// Landmarks evicted by the bounded-memory down-date path.
    pub evictions: u64,
    /// Spectrum share of the smallest positive eigenvalue — the
    /// landmark-sufficiency gauge (small = sufficient).
    pub sufficiency_gap: f64,
    /// Max exact-vs-sketch projection divergence since the last
    /// snapshot publish (shadow-tier streams only).
    pub divergence: Option<f64>,
    /// Hot-path buffer bytes resident (workspace + eigenbasis).
    pub ws_bytes_resident: u64,
    /// Hot-path buffer-growth events since stream start.
    pub ws_reallocs: u64,
    /// Growth events per rank-one update — ≈0 in steady state; the
    /// allocator has left the loop when this stays pinned near zero.
    pub reallocs_per_update: f64,
    /// Engine back-rotation GEMMs dispatched by the stream (fused
    /// batches dispatch one per flush instead of one per update).
    pub engine_gemms: u64,
    /// Projections served through the worker queue.
    pub worker_reads: u64,
    /// Checkpoints of this stream written successfully.
    pub checkpoints: u64,
    /// Write-ahead log records appended for this stream.
    pub wal_appends: u64,
    /// Bytes those appends framed into the log.
    pub wal_bytes: u64,
    /// Failed log appends (stream stayed live; the log has gaps).
    pub wal_errors: u64,
    /// Publication epoch of the stream's latest projection snapshot
    /// (0 = nothing published — still seeding).
    pub snapshot_epoch: u64,
    /// Projections served lock-free from published snapshots.
    pub snapshot_reads: u64,
    /// Accepted points not yet captured by a published snapshot — the
    /// read path's staleness bound right now.
    pub points_since_publish: u64,
}

impl std::fmt::Display for MetricsReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "accepted={} excluded={} errors={} evictions={} suff_gap={:.3e} thru={:.1}/s ingest p50={:.0}µs p99={:.0}µs mean={:.0}µs ws={}B reallocs/update={:.4}",
            self.accepted,
            self.excluded,
            self.errors,
            self.evictions,
            self.sufficiency_gap,
            self.throughput_per_s,
            self.ingest_p50_us,
            self.ingest_p99_us,
            self.ingest_mean_us,
            self.ws_bytes_resident,
            self.reallocs_per_update
        )
    }
}

/// Compact per-stream hot-path gauges, attributed by stream id and
/// shard — the per-stream half of the pool snapshot.
#[derive(Clone, Debug, Default)]
pub struct StreamGauges {
    pub stream: String,
    /// Shard the stream is pinned to.
    pub shard: usize,
    /// Current eigensystem size (or buffered seed count pre-init).
    pub m: usize,
    /// Bytes resident in the stream's hot-path buffers.
    pub ws_bytes_resident: u64,
    /// Cumulative hot-path buffer-growth events.
    pub ws_reallocs: u64,
    /// Growth events per rank-one update — ≈0 in steady state.
    pub reallocs_per_update: f64,
    /// Engine back-rotation GEMMs the stream has dispatched — compare
    /// against `4 × accepted` (adjusted) / `2 × accepted` (unadjusted)
    /// to see the blocked rank-b amortization.
    pub engine_gemms: u64,
    /// Landmarks evicted by the bounded-memory down-date path — moving
    /// while `m` holds flat is the signature of a capped stream.
    pub evictions: u64,
    /// Spectrum share of the smallest positive eigenvalue — the
    /// landmark-sufficiency gauge the eviction policy keys off.
    pub sufficiency_gap: f64,
    /// Max exact-vs-sketch projection divergence since the last
    /// snapshot publish — `Some` only on shadow-tier streams.
    pub divergence: Option<f64>,
    /// Frobenius norm of the latest drift measurement, if any.
    pub drift_frobenius: Option<f64>,
    /// Publication epoch of the latest projection snapshot (0 = none
    /// yet; monotonic per stream, survives migration).
    pub snapshot_epoch: u64,
    /// Projections served lock-free from published snapshots.
    pub snapshot_reads: u64,
    /// Projections served through the worker queue.
    pub worker_reads: u64,
    /// Accepted points not yet captured by a published snapshot.
    pub points_since_publish: u64,
    /// Checkpoints of this stream written successfully.
    pub checkpoints: u64,
    /// Whether this stream was rebuilt by crash recovery.
    pub restored: bool,
}

/// Per-shard occupancy row of a [`PoolSnapshot`] — how the pool's
/// streams and memory are spread over the (elastic) topology, and how
/// much migration traffic each shard has seen.
#[derive(Clone, Debug, Default)]
pub struct ShardOccupancy {
    pub shard: usize,
    /// Whether the shard is a ring member (eligible to receive
    /// streams). Retired workers stay alive to serve stale-handle
    /// forwards and keep their lifetime counters in the rollup, but
    /// get no new placements.
    pub active: bool,
    /// Streams currently owned by this shard.
    pub streams: usize,
    /// Hot-path bytes resident across this shard's streams.
    pub ws_bytes_resident: u64,
    /// Streams migrated onto this shard since spawn.
    pub migrated_in: u64,
    /// Streams migrated off this shard since spawn.
    pub migrated_out: u64,
}

/// Pool-level rollup across all shards and streams: aggregate counters,
/// merged latency distributions, total hot-path residency, summed
/// engine dispatch counts, plus the per-stream gauges and per-shard
/// occupancy for attribution.
/// The counters and latency stats are *lifetime* values — they include
/// streams closed since the pool spawned, so they are monotonic under
/// stream churn (and across migrations: a moved stream's counters
/// travel with it); residency (`total_ws_bytes`) and `per_stream`
/// reflect only the currently open streams.
#[derive(Clone, Debug, Default)]
pub struct PoolSnapshot {
    /// Shard workers behind the router, including retired ones.
    pub shards: usize,
    /// Ring members — shards eligible to own streams (≤ `shards`).
    pub active_shards: usize,
    /// Open streams across the pool.
    pub streams: usize,
    pub accepted: u64,
    pub excluded: u64,
    pub errors: u64,
    /// Landmarks evicted across the pool (lifetime — includes closed
    /// streams). Grows while `total_ws_bytes` holds flat on
    /// bounded-memory deployments.
    pub evictions: u64,
    /// Hot-path bytes resident summed over every stream.
    pub total_ws_bytes: u64,
    /// Workspace-counted engine back-rotation GEMMs summed over every
    /// stream (lifetime — includes streams closed since spawn).
    pub ws_engine_gemms: u64,
    /// Ingest latency over the merged per-stream histograms.
    pub ingest_p50_us: f64,
    pub ingest_p99_us: f64,
    pub ingest_mean_us: f64,
    pub ingest_count: u64,
    pub project_mean_us: f64,
    /// Projections served lock-free from published snapshots, summed
    /// over every stream (lifetime — includes closed streams).
    pub snapshot_reads: u64,
    /// Projections served through the worker queues (lifetime). Flat
    /// `worker_reads` next to a growing `snapshot_reads` is the
    /// acceptance signature of the lock-free read path.
    pub worker_reads: u64,
    /// (native, pjrt) rotation dispatches summed across shard engines.
    pub engine_calls: (u64, u64),
    /// Completed stream migrations since spawn (monotonic — the
    /// elastic-topology activity counter).
    pub migrations: u64,
    /// Commands re-addressed and forwarded by migration tombstones —
    /// stale-handle traffic that arrived at a stream's old shard after
    /// its move and was delivered anyway.
    pub forwards: u64,
    /// Stream checkpoints written successfully (lifetime — includes
    /// closed streams).
    pub checkpoints: u64,
    /// Write-ahead log records appended across the pool (lifetime).
    pub wal_appends: u64,
    /// Bytes framed into the write-ahead logs (lifetime).
    pub wal_bytes: u64,
    /// Failed log appends (lifetime). Streams stay live through append
    /// failures; a nonzero value here means some durability was
    /// forfeited, not that writes were refused.
    pub wal_errors: u64,
    /// Currently open streams that were rebuilt by crash recovery.
    pub recovered_streams: usize,
    /// Max shadow-tier projection divergence across the pool's open
    /// streams (current publish window) — `None` when no stream runs
    /// the shadow tier. One bad sketch anywhere surfaces here.
    pub max_divergence: Option<f64>,
    /// Per-stream gauges, sorted by stream id.
    pub per_stream: Vec<StreamGauges>,
    /// Per-shard occupancy, one row per worker (retired workers are
    /// listed with `active == false`).
    pub per_shard: Vec<ShardOccupancy>,
}

impl std::fmt::Display for PoolSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "pool: shards={}/{} streams={} migrations={} accepted={} excluded={} errors={} evictions={} ws_total={}B ingest p50={:.0}µs p99={:.0}µs mean={:.0}µs (n={}) reads(snapshot,worker)=({},{}) engines(native,pjrt)={:?} wal(appends,bytes,errors)=({},{},{}) checkpoints={} recovered={}",
            self.active_shards,
            self.shards,
            self.streams,
            self.migrations,
            self.accepted,
            self.excluded,
            self.errors,
            self.evictions,
            self.total_ws_bytes,
            self.ingest_p50_us,
            self.ingest_p99_us,
            self.ingest_mean_us,
            self.ingest_count,
            self.snapshot_reads,
            self.worker_reads,
            self.engine_calls,
            self.wal_appends,
            self.wal_bytes,
            self.wal_errors,
            self.checkpoints,
            self.recovered_streams
        )?;
        if let Some(d) = self.max_divergence {
            write!(f, " max_divergence={d:.3e}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let mut h = LatencyHistogram::default();
        for us in [10u64, 20, 30] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_ns() - 20_000.0).abs() < 1.0);
    }

    #[test]
    fn percentile_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let p50 = h.percentile_ns(0.5);
        let p99 = h.percentile_ns(0.99);
        assert!(p50 <= p99);
        assert!(p99 <= h.max_ns * 2.0 + 1.0);
    }

    #[test]
    fn empty_histogram_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_ns(0.99), 0.0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn merge_combines_counts_and_max() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        for us in [5u64, 50] {
            a.record(Duration::from_micros(us));
        }
        for us in [500u64, 5000] {
            b.record(Duration::from_micros(us));
        }
        let (mean_a, mean_b) = (a.mean_ns(), b.mean_ns());
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert!((a.mean_ns() - 0.5 * (mean_a + mean_b)).abs() < 1.0);
        // Percentiles still bracket the merged max.
        assert!(a.percentile_ns(0.99) >= 5_000_000.0 / 2.0);
        let empty = LatencyHistogram::default();
        a.merge(&empty); // merging empty is a no-op
        assert_eq!(a.count(), 4);
    }

    #[test]
    fn pool_snapshot_displays() {
        let snap = PoolSnapshot {
            shards: 3,
            active_shards: 2,
            streams: 4,
            accepted: 100,
            migrations: 5,
            per_stream: vec![StreamGauges { stream: "s0".into(), ..Default::default() }],
            ..Default::default()
        };
        let line = format!("{snap}");
        assert!(line.contains("shards=2/3"));
        assert!(line.contains("streams=4"));
        assert!(line.contains("migrations=5"));
        // Divergence only shows when a shadow-tier stream reported it.
        assert!(!line.contains("max_divergence"));
        let snap = PoolSnapshot { max_divergence: Some(1.5e-3), ..snap };
        assert!(format!("{snap}").contains("max_divergence=1.500e-3"));
    }

    #[test]
    fn percentile_bucket_edges() {
        // ≤ 1 µs lands in bucket 0; the reported edge is tightened by
        // the observed max, so a lone 500 ns sample reads back exactly.
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_nanos(500));
        assert_eq!(h.percentile_ns(1.0), 500.0);
        // Exactly-2× boundary: 2 µs falls in bucket 1 (range
        // (2, 4] µs); min(edge, max) collapses to the sample.
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(2));
        assert_eq!(h.percentile_ns(0.5), 2_000.0);
        // Mid-bucket sample: still bounded by max, not the 4 µs edge.
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(3));
        assert_eq!(h.percentile_ns(0.5), 3_000.0);
        // Below-max sample in a lower bucket keeps the bucket edge.
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(3));
        h.record(Duration::from_micros(100));
        assert_eq!(h.percentile_ns(0.25), 4_000.0);
    }

    #[test]
    fn clamped_last_bucket_reports_true_max() {
        // 60 s lies beyond the 2^25-µs bucket ladder (~33.5 s). The old
        // code returned the clamped bucket's edge, under-reporting the
        // tail; the fix returns the observed maximum.
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(10));
        h.record(Duration::from_secs(60));
        assert_eq!(h.percentile_ns(0.99), 60e9);
        // A >17 s sample below the old edge also reports exactly.
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_secs(20));
        assert_eq!(h.percentile_ns(0.5), 20e9);
    }

    #[test]
    fn percentile_zero_is_first_bucket_bound() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_ns(0.0), 0.0);
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(100));
        // p = 0 resolves at the first (empty) bucket: its 2 µs edge.
        assert_eq!(h.percentile_ns(0.0), 2_000.0);
    }

    #[test]
    fn report_throughput() {
        let mut m = Metrics::default();
        m.accepted = 100;
        let r = m.report();
        assert!(r.throughput_per_s > 0.0);
        assert_eq!(r.accepted, 100);
        // Display renders without panic.
        let _ = format!("{r}");
    }
}
