//! Layer-3 streaming coordinator: bounded-queue ingestion with
//! backpressure, eigenstate ownership, engine routing (native GEMM vs
//! AOT PJRT), periodic drift measurement and latency/throughput metrics.

pub mod drift;
pub mod metrics;
pub mod router;
pub mod server;

pub use drift::{DriftMonitor, DriftPoint};
pub use metrics::{LatencyHistogram, Metrics, MetricsReport};
pub use router::{EnginePolicy, RoutedEngine};
pub use server::{Config, Coordinator, EngineConfig, IngestReply, KernelConfig, Snapshot};
