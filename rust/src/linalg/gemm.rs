//! Blocked, cache-aware, rayon-parallel matrix multiplication and the
//! small BLAS-2 kernels the rest of the crate needs. This is the native
//! compute engine: the same products can also be routed to an AOT PJRT
//! executable via `runtime`/`coordinator::router`.

use super::matrix::Mat;
use crate::util::par;

/// Row-panel height used by the blocked kernel. Chosen so that an
/// `MC × KC` panel of `a` plus a `KC × cols` strip of `b` stay in L2.
const MC: usize = 64;
/// Depth blocking factor.
const KC: usize = 256;
/// Parallelism threshold: below this many flops, threads cost more than
/// they save.
const PAR_FLOPS: usize = 1 << 20;

/// `C = A · B`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    if m == 0 || k == 0 || n == 0 {
        return c;
    }
    let flops = 2 * m * k * n;
    if flops < PAR_FLOPS {
        matmul_serial_into(a, b, &mut c);
    } else {
        matmul_parallel_into(a, b, &mut c);
    }
    c
}

/// `C = A · Bᵀ` without materializing the transpose (both row-major, so
/// this is the dot-product-friendly orientation).
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Mat::zeros(m, n);
    if m == 0 || k == 0 || n == 0 {
        return c;
    }
    let a_data = a.as_slice();
    let b_data = b.as_slice();
    let do_row = |i: usize, crow: &mut [f64]| {
        let arow = &a_data[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b_data[j * k..(j + 1) * k];
            let mut s = 0.0;
            for p in 0..k {
                s += arow[p] * brow[p];
            }
            crow[j] = s;
        }
    };
    if 2 * m * k * n < PAR_FLOPS {
        for i in 0..m {
            do_row(i, &mut c.as_mut_slice()[i * n..(i + 1) * n]);
        }
    } else {
        par::par_chunks_mut(c.as_mut_slice(), n, |i, crow| do_row(i, crow));
    }
    c
}

/// Inner kernel: accumulate rows `i0..i1` of `C` over the `kk..kend`
/// depth slice, with 4-row register blocking — each `brow` load feeds
/// four FMAs, quadrupling arithmetic intensity vs the plain axpy form
/// (the win measured in EXPERIMENTS.md §Perf).
#[inline]
fn gemm_panel(
    a_data: &[f64],
    b_data: &[f64],
    c_panel: &mut [f64],
    i0: usize,
    i1: usize,
    k: usize,
    n: usize,
    kk: usize,
    kend: usize,
) {
    let mut i = i0;
    while i + 4 <= i1 {
        // Split the 4 destination rows without aliasing.
        let base = (i - i0) * n;
        let (r0, rest) = c_panel[base..].split_at_mut(n);
        let (r1, rest) = rest.split_at_mut(n);
        let (r2, rest) = rest.split_at_mut(n);
        let r3 = &mut rest[..n];
        for p in kk..kend {
            let a0 = a_data[i * k + p];
            let a1 = a_data[(i + 1) * k + p];
            let a2 = a_data[(i + 2) * k + p];
            let a3 = a_data[(i + 3) * k + p];
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue;
            }
            let brow = &b_data[p * n..(p + 1) * n];
            for j in 0..n {
                let bj = brow[j];
                r0[j] += a0 * bj;
                r1[j] += a1 * bj;
                r2[j] += a2 * bj;
                r3[j] += a3 * bj;
            }
        }
        i += 4;
    }
    while i < i1 {
        let crow = &mut c_panel[(i - i0) * n..(i - i0 + 1) * n];
        for p in kk..kend {
            let aip = a_data[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b_data[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
        i += 1;
    }
}

fn matmul_serial_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let a_data = a.as_slice();
    let b_data = b.as_slice();
    let c_data = c.as_mut_slice();
    for kk in (0..k).step_by(KC) {
        let kend = (kk + KC).min(k);
        gemm_panel(a_data, b_data, c_data, 0, m, k, n, kk, kend);
    }
}

fn matmul_parallel_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let a_data = a.as_slice();
    let b_data = b.as_slice();
    par::par_chunks_mut(c.as_mut_slice(), MC * n, |blk, c_panel| {
        let i0 = blk * MC;
        let i1 = (i0 + MC).min(m);
        for kk in (0..k).step_by(KC) {
            let kend = (kk + KC).min(k);
            gemm_panel(a_data, b_data, c_panel, i0, i1, k, n, kk, kend);
        }
    });
}

/// `y = A · x`.
pub fn gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len(), "gemv shape mismatch");
    (0..a.rows())
        .map(|i| super::matrix::dot(a.row(i), x))
        .collect()
}

/// `y = Aᵀ · x`.
pub fn gemv_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len(), "gemv_t shape mismatch");
    let mut y = vec![0.0; a.cols()];
    for i in 0..a.rows() {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        for j in 0..a.cols() {
            y[j] += xi * row[j];
        }
    }
    y
}

/// Gram matrix `A · Aᵀ` (symmetric; computes the upper triangle once).
pub fn syrk(a: &Mat) -> Mat {
    let (m, k) = (a.rows(), a.cols());
    let mut c = Mat::zeros(m, m);
    let a_data = a.as_slice();
    let upper_row = |i: usize| -> Vec<f64> {
        let ai = &a_data[i * k..(i + 1) * k];
        (i..m)
            .map(|j| {
                let aj = &a_data[j * k..(j + 1) * k];
                super::matrix::dot(ai, aj)
            })
            .collect()
    };
    let results: Vec<Vec<f64>> = if 2 * m * m * k >= PAR_FLOPS {
        par::par_map(m, 1, upper_row)
    } else {
        (0..m).map(upper_row).collect()
    };
    for (i, rowvals) in results.into_iter().enumerate() {
        for (off, v) in rowvals.into_iter().enumerate() {
            let j = i + off;
            c[(i, j)] = v;
            c[(j, i)] = v;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|p| a[(i, p)] * b[(p, j)]).sum()
        })
    }

    #[test]
    fn matmul_matches_naive_small() {
        let a = Mat::from_fn(5, 7, |i, j| (i as f64 - j as f64) * 0.3);
        let b = Mat::from_fn(7, 4, |i, j| (i * j) as f64 * 0.1 + 1.0);
        let c = matmul(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-12);
    }

    #[test]
    fn matmul_matches_naive_blocked_sizes() {
        // Exercise the KC blocking boundary and parallel path.
        let a = Mat::from_fn(70, 300, |i, j| ((i * 7 + j * 13) % 11) as f64 - 5.0);
        let b = Mat::from_fn(300, 65, |i, j| ((i * 3 + j * 17) % 13) as f64 * 0.25);
        let c = matmul(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-9);
    }

    #[test]
    fn matmul_nt_matches() {
        let a = Mat::from_fn(6, 9, |i, j| (i + j) as f64 * 0.5);
        let b = Mat::from_fn(8, 9, |i, j| i as f64 * 1.5 - j as f64);
        let c = matmul_nt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn gemv_matches() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let x = vec![1.0, -1.0, 2.0];
        let y = gemv(&a, &x);
        for i in 0..4 {
            let expect: f64 = (0..3).map(|j| a[(i, j)] * x[j]).sum();
            assert!((y[i] - expect).abs() < 1e-13);
        }
    }

    #[test]
    fn gemv_t_matches() {
        let a = Mat::from_fn(4, 3, |i, j| ((i * 3 + j) as f64).sin());
        let x = vec![0.5, 1.5, -2.0, 3.0];
        let y = gemv_t(&a, &x);
        let yt = gemv(&a.transpose(), &x);
        for (u, v) in y.iter().zip(yt.iter()) {
            assert!((u - v).abs() < 1e-13);
        }
    }

    #[test]
    fn syrk_matches_matmul() {
        let a = Mat::from_fn(10, 6, |i, j| ((i + 2 * j) as f64).cos());
        let c = syrk(&a);
        let c2 = matmul_nt(&a, &a);
        assert!(c.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn empty_shapes() {
        let a = Mat::zeros(0, 3);
        let b = Mat::zeros(3, 2);
        let c = matmul(&a, &b);
        assert_eq!(c.rows(), 0);
        assert_eq!(c.cols(), 2);
    }
}
