//! Artifact manifest and the shape-bucket ladder. `aot.py` lowers every
//! L2 function at each bucket size; the runtime picks the smallest
//! bucket ≥ the live problem size and pads inputs per the contract in
//! [`crate::runtime::pad`].

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact entry from `manifest.tsv`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub m: usize,
    pub dim: usize,
    pub path: PathBuf,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// kind → bucket sizes ascending.
    buckets: HashMap<String, Vec<usize>>,
    /// (kind, m) → meta.
    entries: HashMap<(String, usize), ArtifactMeta>,
    /// Feature-dimension pad target (constant across artifacts).
    pub dim: usize,
}

impl Manifest {
    /// Load `manifest.tsv` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<Self, String> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e} (run `make artifacts`)", path.display()))?;
        let mut manifest = Manifest::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 5 {
                return Err(format!("manifest: bad row '{line}'"));
            }
            let meta = ArtifactMeta {
                name: f[0].to_string(),
                kind: f[1].to_string(),
                m: f[2].parse().map_err(|e| format!("manifest m: {e}"))?,
                dim: f[3].parse().map_err(|e| format!("manifest dim: {e}"))?,
                path: dir.join(f[4]),
            };
            if !meta.path.exists() {
                return Err(format!("artifact file missing: {}", meta.path.display()));
            }
            manifest.dim = meta.dim;
            manifest.buckets.entry(meta.kind.clone()).or_default().push(meta.m);
            manifest.entries.insert((meta.kind.clone(), meta.m), meta);
        }
        for v in manifest.buckets.values_mut() {
            v.sort_unstable();
            v.dedup();
        }
        if manifest.entries.is_empty() {
            return Err("manifest: no artifacts".into());
        }
        Ok(manifest)
    }

    /// Bucket sizes available for an artifact kind, ascending.
    pub fn buckets(&self, kind: &str) -> &[usize] {
        self.buckets.get(kind).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Smallest bucket that fits `size`.
    pub fn bucket_for(&self, kind: &str, size: usize) -> Option<usize> {
        self.buckets(kind).iter().copied().find(|&b| b >= size)
    }

    /// Artifact metadata for `(kind, bucket)`.
    pub fn entry(&self, kind: &str, bucket: usize) -> Option<&ArtifactMeta> {
        self.entries.get(&(kind.to_string(), bucket))
    }

    pub fn kinds(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.buckets.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, rows: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        let mut text = String::from("# header\n");
        for r in rows {
            text.push_str(r);
            text.push('\n');
            let path = r.split('\t').last().unwrap();
            std::fs::write(dir.join(path), "HloModule stub").unwrap();
        }
        std::fs::write(dir.join("manifest.tsv"), text).unwrap();
    }

    #[test]
    fn loads_and_selects_buckets() {
        let dir = std::env::temp_dir().join("inkpca_manifest_test");
        write_manifest(
            &dir,
            &[
                "gram_64\tgram\t64\t16\tgram_64.hlo.txt",
                "gram_256\tgram\t256\t16\tgram_256.hlo.txt",
                "gram_128\tgram\t128\t16\tgram_128.hlo.txt",
            ],
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.buckets("gram"), &[64, 128, 256]);
        assert_eq!(m.bucket_for("gram", 1), Some(64));
        assert_eq!(m.bucket_for("gram", 64), Some(64));
        assert_eq!(m.bucket_for("gram", 65), Some(128));
        assert_eq!(m.bucket_for("gram", 300), None);
        assert_eq!(m.dim, 16);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join("inkpca_manifest_missing");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), "x\tgram\t64\t16\tnope.hlo.txt\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_manifest_if_present() {
        let dir = Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(dir).unwrap();
            for kind in ["kernel_column", "eigvec_update", "gram", "nystrom_reconstruct"] {
                assert!(!m.buckets(kind).is_empty(), "missing artifacts for {kind}");
            }
        }
    }
}
