//! Random Fourier features + frequent directions: the sketched KPCA
//! tier (Ghashami, Perry & Phillips, *Streaming Kernel PCA*,
//! 1512.05059).
//!
//! The exact engine ([`crate::kpca::IncrementalKpca`]) pays O(m·r) per
//! update and O(m²) memory in the landmark count m. This module tracks
//! the same top-r kernel principal subspace in **fixed** memory with
//! per-update cost independent of m, in two moves:
//!
//! 1. **Random Fourier features** ([`RffMap`]): for the RBF kernel
//!    `k(x, y) = exp(−‖x−y‖²/σ)` (the repo's parameterization — spectral
//!    measure `ω ~ N(0, (2/σ)·I)`), the explicit D-dimensional map
//!    `z_i(x) = √(2/D)·cos(ωᵢᵀx + bᵢ)` satisfies
//!    `E[z(x)ᵀz(y)] = k(x, y)`. Kernel PCA on the stream becomes
//!    *linear* PCA on the feature stream `z(x₁), z(x₂), …`. The map is
//!    seeded ([`crate::util::Rng`]), so a checkpoint only persists the
//!    seed — restore regenerates bit-identical `ω`/`b`.
//! 2. **Frequent directions** ([`RffKpca`]): a 2r×D sketch `B` absorbs
//!    feature rows one at a time; when full, one 2r×2r eigensolve
//!    shrinks every retained direction by the (r+1)-th energy δ and
//!    keeps the top r rows. `BᵀB ⪯ ZᵀZ ⪯ BᵀB + δₜₒₜ·I` — the classic
//!    FD guarantee, inherited for the kernel Gram through the feature
//!    map. Per-point cost is O(D·dim + D·r) amortized; the eigensolve
//!    is O(r³ + r²·D) once every r points.
//!
//! Eigenvalue bridge: the Gram matrix `ZZᵀ` (what the exact engine
//! diagonalizes) and the covariance `ZᵀZ` (what the sketch tracks)
//! share nonzero eigenvalues, so the sketch's σ²ₖ estimate the exact
//! tier's λₖ directly and [`RffKpca::project`] needs **no** 1/√λ
//! rescaling: the exact score `uₖᵀk_y/√λₖ` corresponds to `vₖᵀz(y)`
//! with `vₖ` the unit right singular vector.
//!
//! Mean adjustment is streamed: each arriving feature vector is
//! centered against the running mean *before* it enters the sketch
//! (`μ ← μ + z_c/n` afterwards). This is the standard streaming
//! approximation — early points are centered against a younger mean —
//! and is covered by the documented sketch tolerance in
//! `tests/tiers.rs`.

use std::f64::consts::PI;
use std::sync::Arc;

use crate::kpca::{BatchOutcome, KpcaStats};
use crate::linalg::{eigh, matmul_nt_into_buf, Mat, MatView, MatViewMut, PackBuffers};
use crate::util::Rng;

/// Floor under which a sketch singular value is treated as zero.
const VAL_FLOOR: f64 = 1e-12;

/// A seeded random Fourier feature map for the RBF kernel
/// `exp(−‖x−y‖²/σ)`.
///
/// Cheap to clone (the `ω`/`b` tables are behind `Arc`s) so a
/// published [`crate::coordinator::ProjectionSnapshot`] can carry the
/// map without copying `D·dim` doubles per publish.
#[derive(Clone)]
pub struct RffMap {
    dim: usize,
    features: usize,
    sigma: f64,
    seed: u64,
    /// Frequencies, `features × dim` row-major.
    omega: Arc<Vec<f64>>,
    /// Phases, one per feature.
    phases: Arc<Vec<f64>>,
    /// `√(2/D)` amplitude.
    scale: f64,
}

impl RffMap {
    /// Draw the map for `exp(−‖x−y‖²/σ)`. Deterministic in `seed`:
    /// all `features·dim` frequencies are drawn first, then the
    /// `features` phases — the generation order is part of the
    /// checkpoint contract (restore regenerates the same map from the
    /// persisted seed).
    pub fn new(dim: usize, features: usize, sigma: f64, seed: u64) -> Result<RffMap, String> {
        if dim == 0 {
            return Err("rff map needs dim >= 1".into());
        }
        if features == 0 {
            return Err("rff map needs features >= 1".into());
        }
        if !(sigma.is_finite() && sigma > 0.0) {
            return Err(format!("rff map needs a positive finite sigma, got {sigma}"));
        }
        let mut rng = Rng::new(seed);
        let w = (2.0 / sigma).sqrt();
        let mut omega = Vec::with_capacity(features * dim);
        for _ in 0..features * dim {
            omega.push(rng.normal() * w);
        }
        let mut phases = Vec::with_capacity(features);
        for _ in 0..features {
            phases.push(rng.range(0.0, 2.0 * PI));
        }
        let scale = (2.0 / features as f64).sqrt();
        Ok(RffMap {
            dim,
            features,
            sigma,
            seed,
            omega: Arc::new(omega),
            phases: Arc::new(phases),
            scale,
        })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn features(&self) -> usize {
        self.features
    }

    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Map one point: `z[i] = √(2/D)·cos(ωᵢᵀx + bᵢ)`. `z` must hold
    /// exactly `features` slots.
    pub fn map_into(&self, x: &[f64], z: &mut [f64]) {
        assert_eq!(x.len(), self.dim, "rff map: point dim mismatch");
        assert_eq!(z.len(), self.features, "rff map: output len mismatch");
        for (i, zi) in z.iter_mut().enumerate() {
            let row = &self.omega[i * self.dim..(i + 1) * self.dim];
            let mut acc = self.phases[i];
            for (w, v) in row.iter().zip(x) {
                acc += w * v;
            }
            *zi = self.scale * acc.cos();
        }
    }

    /// Map a block of `b` points (flat row-major `b × dim`) into
    /// `out` (`b × features` row-major): one `Y·Ωᵀ` GEMM through the
    /// packed kernel, then the cosine transform in place.
    pub fn map_block_into(
        &self,
        ys: &[f64],
        b: usize,
        out: &mut Vec<f64>,
        pack: &mut PackBuffers,
    ) {
        assert_eq!(ys.len(), b * self.dim, "rff map: block shape mismatch");
        out.clear();
        out.resize(b * self.features, 0.0);
        {
            let yv = MatView::of_rows(ys, b, self.dim);
            let ov = MatView::of_rows(&self.omega, self.features, self.dim);
            let mut outv = MatViewMut::new(out, b, self.features, self.features);
            matmul_nt_into_buf(yv, ov, &mut outv, pack);
        }
        for r in 0..b {
            let row = &mut out[r * self.features..(r + 1) * self.features];
            for (v, ph) in row.iter_mut().zip(self.phases.iter()) {
                *v = self.scale * (*v + ph).cos();
            }
        }
    }

    /// Bytes resident in the frequency/phase tables.
    pub fn bytes_resident(&self) -> usize {
        (self.omega.capacity() + self.phases.capacity()) * std::mem::size_of::<f64>()
    }
}

/// Everything an [`RffKpca`] needs to come back after a crash. The
/// `ω`/`b` tables are *not* persisted — they regenerate from `seed`.
#[derive(Clone, Debug)]
pub struct RffParts {
    pub seed: u64,
    pub sigma: f64,
    pub dim: usize,
    pub features: usize,
    pub sketch_r: usize,
    pub mean_adjust: bool,
    /// Points absorbed (seed included).
    pub count: u64,
    /// Running feature mean (`features`, all zeros when unadjusted).
    pub mu: Vec<f64>,
    /// Occupied sketch rows, flat row-major `brows × features`.
    pub b: Vec<f64>,
    pub brows: usize,
    pub stats: KpcaStats,
}

/// The sketched engine: a frequent-directions sketch over the RFF
/// feature stream. Fixed memory (`2r × D` sketch + `D`-dim mean),
/// O(D·dim + D·r) amortized per point — independent of how many points
/// the stream has absorbed.
pub struct RffKpca {
    map: RffMap,
    sketch_r: usize,
    /// Sketch row capacity, `2·sketch_r`.
    ell: usize,
    mean_adjust: bool,
    count: u64,
    mu: Vec<f64>,
    /// Sketch rows, flat row-major `ell × features`; `brows` occupied.
    b: Vec<f64>,
    brows: usize,
    /// Cached spectrum/basis of the current sketch (lazy; see
    /// [`RffKpca::refresh_basis`]). `vals` descending σ², `basis`
    /// `features × basis_k` row-major (columns = unit right singular
    /// vectors).
    vals: Vec<f64>,
    basis: Vec<f64>,
    basis_k: usize,
    dirty: bool,
    stats: KpcaStats,
    shrinks: u64,
    mask: Vec<bool>,
    /// Feature-vector scratch.
    z: Vec<f64>,
    /// Shrink scratch (`sketch_r × features`).
    newb: Vec<f64>,
    pack: PackBuffers,
}

impl RffKpca {
    pub fn new(
        dim: usize,
        features: usize,
        sketch_r: usize,
        sigma: f64,
        seed: u64,
        mean_adjust: bool,
    ) -> Result<RffKpca, String> {
        if sketch_r == 0 {
            return Err("rff tier needs sketch_r >= 1".into());
        }
        if features < 2 * sketch_r {
            return Err(format!(
                "rff tier needs features >= 2*sketch_r (got D={features}, r={sketch_r})"
            ));
        }
        let map = RffMap::new(dim, features, sigma, seed)?;
        let ell = 2 * sketch_r;
        Ok(RffKpca {
            map,
            sketch_r,
            ell,
            mean_adjust,
            count: 0,
            mu: vec![0.0; features],
            b: vec![0.0; ell * features],
            brows: 0,
            vals: Vec::new(),
            basis: Vec::new(),
            basis_k: 0,
            dirty: true,
            stats: KpcaStats::default(),
            shrinks: 0,
            mask: Vec::new(),
            z: vec![0.0; features],
            newb: Vec::new(),
            pack: PackBuffers::new(),
        })
    }

    /// Rebuild from checkpoint parts; the feature map regenerates from
    /// the persisted seed.
    pub fn from_parts(p: RffParts) -> Result<RffKpca, String> {
        let mut st = RffKpca::new(p.dim, p.features, p.sketch_r, p.sigma, p.seed, p.mean_adjust)?;
        if p.mu.len() != p.features {
            return Err("rff parts: mean length mismatch".into());
        }
        if p.brows > st.ell || p.b.len() != p.brows * p.features {
            return Err("rff parts: sketch shape mismatch".into());
        }
        st.mu.copy_from_slice(&p.mu);
        st.b[..p.b.len()].copy_from_slice(&p.b);
        st.brows = p.brows;
        st.count = p.count;
        st.stats = p.stats;
        st.dirty = true;
        Ok(st)
    }

    pub fn to_parts(&self) -> RffParts {
        RffParts {
            seed: self.map.seed(),
            sigma: self.map.sigma(),
            dim: self.map.dim(),
            features: self.map.features(),
            sketch_r: self.sketch_r,
            mean_adjust: self.mean_adjust,
            count: self.count,
            mu: self.mu.clone(),
            b: self.b[..self.brows * self.map.features()].to_vec(),
            brows: self.brows,
            stats: self.stats,
        }
    }

    pub fn map(&self) -> &RffMap {
        &self.map
    }

    pub fn dim(&self) -> usize {
        self.map.dim()
    }

    pub fn sketch_r(&self) -> usize {
        self.sketch_r
    }

    pub fn mean_adjust(&self) -> bool {
        self.mean_adjust
    }

    /// Points absorbed. The sketch holds *directions*, not landmarks —
    /// unlike the exact tier this is not a resident-row count.
    pub fn len(&self) -> usize {
        usize::try_from(self.count).unwrap_or(usize::MAX)
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn stats(&self) -> KpcaStats {
        self.stats
    }

    /// Sketch shrink cycles performed (one per 2r absorbed rows).
    pub fn shrinks(&self) -> u64 {
        self.shrinks
    }

    pub fn last_batch_mask(&self) -> &[bool] {
        &self.mask
    }

    /// Absorb one point: map to feature space, center against the
    /// running mean, append to the sketch, shrink when full. Every
    /// point is accepted — the sketch has no rank-deficiency exclusion.
    pub fn push(&mut self, x: &[f64]) -> Result<bool, String> {
        if x.len() != self.map.dim() {
            return Err(format!(
                "rff push: expected dim {}, got {}",
                self.map.dim(),
                x.len()
            ));
        }
        let features = self.map.features();
        let mut z = std::mem::take(&mut self.z);
        self.map.map_into(x, &mut z);
        self.count += 1;
        if self.mean_adjust {
            let n = self.count as f64;
            for (zi, mi) in z.iter_mut().zip(self.mu.iter_mut()) {
                *zi -= *mi;
                *mi += *zi / n;
            }
        }
        self.b[self.brows * features..(self.brows + 1) * features].copy_from_slice(&z);
        self.brows += 1;
        self.z = z;
        self.dirty = true;
        self.stats.accepted += 1;
        self.stats.updates += 1;
        if self.brows == self.ell {
            self.shrink()?;
        }
        Ok(true)
    }

    /// Absorb a flat row-major batch. The per-batch mask mirrors the
    /// exact tier's ([`crate::kpca::IncrementalKpca::last_batch_mask`]);
    /// here it is all-true because the sketch excludes nothing.
    pub fn push_batch(&mut self, xs: &[f64]) -> Result<BatchOutcome, String> {
        let dim = self.map.dim();
        if dim == 0 || xs.len() % dim != 0 {
            return Err("rff push_batch: flat batch not a multiple of dim".into());
        }
        let b = xs.len() / dim;
        self.mask.clear();
        for p in 0..b {
            self.push(&xs[p * dim..(p + 1) * dim])?;
            self.mask.push(true);
        }
        Ok(BatchOutcome { accepted: b, excluded: 0 })
    }

    /// Frequent-directions shrink: eigendecompose the small Gram
    /// `G = BBᵀ` (2r × 2r), subtract the (r+1)-th energy δ from every
    /// direction, keep the top r re-scaled rows `√((σ²ₖ−δ)/σ²ₖ)·uₖᵀB`.
    fn shrink(&mut self) -> Result<(), String> {
        let features = self.map.features();
        let n = self.brows;
        let mut g = Mat::zeros(n, n);
        {
            let bv = MatView::of_rows(&self.b[..n * features], n, features);
            let mut gv = g.view_mut();
            matmul_nt_into_buf(bv, bv, &mut gv, &mut self.pack);
        }
        let eg = eigh(&g)?;
        // Ascending values: the (r+1)-th largest energy sits at
        // `n - 1 - sketch_r`.
        let delta = eg.values[n - 1 - self.sketch_r].max(0.0);
        self.newb.clear();
        self.newb.resize(self.sketch_r * features, 0.0);
        for t in 0..self.sketch_r {
            let idx = n - 1 - t;
            let lam = eg.values[idx];
            if lam <= VAL_FLOOR {
                continue;
            }
            let w = ((lam - delta).max(0.0) / lam).sqrt();
            if w == 0.0 {
                continue;
            }
            let dst = &mut self.newb[t * features..(t + 1) * features];
            for j in 0..n {
                let c = eg.vectors.row(j)[idx];
                if c != 0.0 {
                    let src = &self.b[j * features..(j + 1) * features];
                    for (d, s) in dst.iter_mut().zip(src) {
                        *d += c * s;
                    }
                }
            }
            for d in dst.iter_mut() {
                *d *= w;
            }
        }
        self.b[..self.sketch_r * features].copy_from_slice(&self.newb);
        self.brows = self.sketch_r;
        self.shrinks += 1;
        self.stats.deflated += 1;
        self.dirty = true;
        Ok(())
    }

    /// Recompute the cached spectrum + projection basis from the
    /// current sketch rows (one 2r×2r eigensolve + an O(r·D) scatter).
    /// Lazy: gauges and pushes never pay for it, only capture /
    /// project / `top_values` do, and only when the sketch changed.
    /// Returns the number of usable components.
    pub fn refresh_basis(&mut self) -> usize {
        if !self.dirty {
            return self.basis_k;
        }
        let features = self.map.features();
        let n = self.brows;
        if n == 0 {
            self.vals.clear();
            self.basis.clear();
            self.basis_k = 0;
            self.dirty = false;
            return 0;
        }
        let mut g = Mat::zeros(n, n);
        {
            let bv = MatView::of_rows(&self.b[..n * features], n, features);
            let mut gv = g.view_mut();
            matmul_nt_into_buf(bv, bv, &mut gv, &mut self.pack);
        }
        let eg = match eigh(&g) {
            Ok(e) => e,
            Err(_) => {
                // A non-converging 2r×2r eigensolve leaves the previous
                // basis in place rather than poisoning the read path.
                self.dirty = false;
                return self.basis_k;
            }
        };
        let k = self.sketch_r.min(n);
        self.vals.clear();
        self.basis.clear();
        self.basis.resize(features * k, 0.0);
        let mut col = vec![0.0; features];
        for t in 0..k {
            let idx = n - 1 - t;
            let lam = eg.values[idx].max(0.0);
            self.vals.push(lam);
            if lam <= VAL_FLOOR {
                continue;
            }
            let inv = 1.0 / lam.sqrt();
            col.iter_mut().for_each(|c| *c = 0.0);
            for j in 0..n {
                let c = eg.vectors.row(j)[idx];
                if c != 0.0 {
                    let src = &self.b[j * features..(j + 1) * features];
                    for (d, s) in col.iter_mut().zip(src) {
                        *d += c * s;
                    }
                }
            }
            for (f, v) in col.iter().enumerate() {
                self.basis[f * k + t] = v * inv;
            }
        }
        self.basis_k = k;
        self.dirty = false;
        k
    }

    /// The last materialized spectrum, descending (possibly stale —
    /// refreshed by capture / project / [`RffKpca::top_values`]).
    pub fn cached_values(&self) -> &[f64] {
        &self.vals
    }

    /// Top-`k` sketch eigenvalue estimates, descending (σ²ₖ of the
    /// sketch ≈ the exact tier's λₖ; see the module docs).
    pub fn top_values(&mut self, k: usize) -> Vec<f64> {
        let avail = self.refresh_basis();
        self.vals[..k.min(avail)].to_vec()
    }

    /// `λ⁺_min / Σλ⁺` over the sketch spectrum — same monitor contract
    /// as [`crate::kpca::IncrementalKpca::sufficiency_gap`].
    pub fn sufficiency_gap(&mut self) -> f64 {
        self.refresh_basis();
        let mut total = 0.0;
        let mut min_pos = f64::INFINITY;
        for &l in &self.vals {
            if l > 0.0 {
                total += l;
                if l < min_pos {
                    min_pos = l;
                }
            }
        }
        if total > 0.0 && min_pos.is_finite() {
            min_pos / total
        } else {
            0.0
        }
    }

    /// Project one point onto the top `r` sketched components:
    /// `scoreₖ = vₖᵀ(z(y) − μ)`. No 1/√λ rescaling — see the module
    /// docs for the Gram/covariance bridge.
    pub fn project(&mut self, y: &[f64], r: usize) -> Vec<f64> {
        assert_eq!(y.len(), self.map.dim(), "rff project: dim mismatch");
        let avail = self.refresh_basis();
        let r_eff = r.min(avail);
        let mut z = std::mem::take(&mut self.z);
        self.map.map_into(y, &mut z);
        if self.mean_adjust {
            for (zi, mi) in z.iter_mut().zip(self.mu.iter()) {
                *zi -= *mi;
            }
        }
        let k = self.basis_k;
        let mut out = vec![0.0; r_eff];
        for (c, o) in out.iter_mut().enumerate() {
            if self.vals[c] <= VAL_FLOOR {
                continue;
            }
            let mut acc = 0.0;
            for (f, zi) in z.iter().enumerate() {
                acc += zi * self.basis[f * k + c];
            }
            *o = acc;
        }
        self.z = z;
        out
    }

    /// Snapshot pieces for the lock-free read path: the (cheaply
    /// cloned) feature map, the mean, and a copied `features × r`
    /// prefix of the basis with its descending values. `None` until
    /// the sketch has at least one usable component.
    pub fn snapshot_parts(
        &mut self,
        r_limit: usize,
    ) -> Option<(RffMap, Vec<f64>, Vec<f64>, Vec<f64>)> {
        let avail = self.refresh_basis();
        if avail == 0 {
            return None;
        }
        let r = if r_limit == 0 { avail } else { r_limit.min(avail) };
        let features = self.map.features();
        let k = self.basis_k;
        let mut basis = vec![0.0; features * r];
        for f in 0..features {
            basis[f * r..(f + 1) * r].copy_from_slice(&self.basis[f * k..f * k + r]);
        }
        Some((
            self.map.clone(),
            self.mu.clone(),
            basis,
            self.vals[..r].to_vec(),
        ))
    }

    /// Bytes resident across the sketch, mean, cached basis, feature
    /// map and scratch.
    pub fn bytes_resident(&self) -> usize {
        let f64s = self.b.capacity()
            + self.mu.capacity()
            + self.vals.capacity()
            + self.basis.capacity()
            + self.z.capacity()
            + self.newb.capacity();
        f64s * std::mem::size_of::<f64>() + self.map.bytes_resident() + self.pack.bytes_resident()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Rbf;
    use crate::kernels::Kernel;

    fn stream(n: usize, dim: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::with_capacity(n * dim);
        for i in 0..n {
            for d in 0..dim {
                // Two clusters plus noise — correlated coordinates so
                // the top subspace is meaningful.
                let base = if i % 2 == 0 { 1.0 } else { -1.0 };
                xs.push(base * (1.0 + d as f64 * 0.3) + 0.25 * rng.normal());
            }
        }
        xs
    }

    #[test]
    fn map_is_deterministic_in_seed_and_approximates_the_kernel() {
        let dim = 4;
        let sigma = 2.0;
        let a = RffMap::new(dim, 4096, sigma, 42).unwrap();
        let b = RffMap::new(dim, 4096, sigma, 42).unwrap();
        let x = [0.3, -0.7, 1.1, 0.2];
        let y = [-0.4, 0.5, 0.9, -1.0];
        let mut za = vec![0.0; 4096];
        let mut zb = vec![0.0; 4096];
        a.map_into(&x, &mut za);
        b.map_into(&x, &mut zb);
        assert_eq!(za, zb, "same seed must give a bit-identical map");

        let mut zy = vec![0.0; 4096];
        a.map_into(&y, &mut zy);
        let approx: f64 = za.iter().zip(&zy).map(|(p, q)| p * q).sum();
        let exact = Rbf { sigma }.eval(&x, &y);
        assert!(
            (approx - exact).abs() < 0.05,
            "RFF inner product {approx} should approximate k(x,y)={exact}"
        );
    }

    #[test]
    fn block_map_matches_pointwise_map() {
        let dim = 3;
        let map = RffMap::new(dim, 64, 1.5, 7).unwrap();
        let xs = stream(9, dim, 3);
        let mut block = Vec::new();
        let mut pack = PackBuffers::new();
        map.map_block_into(&xs, 9, &mut block, &mut pack);
        let mut z = vec![0.0; 64];
        for p in 0..9 {
            map.map_into(&xs[p * dim..(p + 1) * dim], &mut z);
            for (i, zi) in z.iter().enumerate() {
                assert!(
                    (block[p * 64 + i] - zi).abs() < 1e-12,
                    "block map row {p} feature {i} diverged"
                );
            }
        }
    }

    #[test]
    fn sketch_memory_is_fixed_and_values_are_sorted() {
        let dim = 3;
        let mut st = RffKpca::new(dim, 64, 8, 1.5, 11, true).unwrap();
        let xs = stream(400, dim, 5);
        let before = st.bytes_resident();
        for p in 0..400 {
            st.push(&xs[p * dim..(p + 1) * dim]).unwrap();
        }
        assert_eq!(st.len(), 400);
        assert!(st.shrinks() > 0, "400 points through a 16-row sketch must shrink");
        assert_eq!(
            st.bytes_resident(),
            before,
            "sketch memory must not grow with the stream"
        );
        let vals = st.top_values(8);
        assert!(!vals.is_empty());
        for w in vals.windows(2) {
            assert!(w[0] >= w[1], "values must be descending: {vals:?}");
        }
        assert!(vals[0] > 0.0);
    }

    #[test]
    fn projection_tracks_batch_pca_on_the_feature_stream() {
        // Oracle: exact PCA of the centered feature matrix Z. The FD
        // sketch must reproduce the top principal score up to sign
        // within the FD error bound (generous tolerance — this pins
        // "tracks the subspace", not bit-equality).
        let dim = 3;
        let features = 128;
        let n = 240;
        let xs = stream(n, dim, 9);
        let mut st = RffKpca::new(dim, features, 6, 1.5, 21, true).unwrap();
        st.push_batch(&xs).unwrap();

        // Batch oracle in feature space, same map, exact mean.
        let map = st.map().clone();
        let mut z = vec![0.0; features];
        let mut zmat = Vec::with_capacity(n * features);
        for p in 0..n {
            map.map_into(&xs[p * dim..(p + 1) * dim], &mut z);
            zmat.extend_from_slice(&z);
        }
        let mut mean = vec![0.0; features];
        for p in 0..n {
            for f in 0..features {
                mean[f] += zmat[p * features + f];
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        for p in 0..n {
            for f in 0..features {
                zmat[p * features + f] -= mean[f];
            }
        }
        // Covariance ZᵀZ top eigenvector via the n×n Gram trick would
        // be O(n³); the sketch dimension is small enough to eigensolve
        // the D×D covariance directly here (test-only cost).
        let mut cov = Mat::zeros(features, features);
        for p in 0..n {
            cov.syr(1.0, &zmat[p * features..(p + 1) * features]);
        }
        cov.symmetrize();
        let eg = eigh(&cov).unwrap();
        let top = features - 1;
        let y = &xs[0..dim];
        map.map_into(y, &mut z);
        let mut zc = z.clone();
        for (zi, mi) in zc.iter_mut().zip(&mean) {
            *zi -= *mi;
        }
        let mut oracle = 0.0;
        for f in 0..features {
            oracle += zc[f] * eg.vectors.row(f)[top];
        }

        let got = st.project(y, 1);
        assert_eq!(got.len(), 1);
        let d = (got[0].abs() - oracle.abs()).abs();
        let scale = oracle.abs().max(1e-6);
        assert!(
            d / scale < 0.35,
            "sketched top score {} vs batch feature-PCA oracle {} (rel diff {})",
            got[0],
            oracle,
            d / scale
        );
    }

    #[test]
    fn parts_roundtrip_is_exact() {
        let dim = 3;
        let xs = stream(120, dim, 13);
        let mut st = RffKpca::new(dim, 64, 6, 1.5, 17, true).unwrap();
        st.push_batch(&xs).unwrap();
        let parts = st.to_parts();
        let mut back = RffKpca::from_parts(parts).unwrap();
        assert_eq!(back.len(), st.len());
        let y = &xs[0..dim];
        let a = st.project(y, 4);
        let b = back.project(y, 4);
        assert_eq!(a.len(), b.len());
        for (p, q) in a.iter().zip(&b) {
            assert!(
                (p - q).abs() < 1e-12,
                "restored sketch must project identically: {a:?} vs {b:?}"
            );
        }
        // And the restored engine keeps absorbing.
        let more = stream(40, dim, 14);
        back.push_batch(&more).unwrap();
        assert_eq!(back.len(), 160);
    }

    #[test]
    fn malformed_inputs_error_cleanly() {
        assert!(RffKpca::new(3, 8, 8, 1.5, 1, true).is_err(), "D < 2r must be rejected");
        assert!(RffKpca::new(3, 64, 0, 1.5, 1, true).is_err());
        assert!(RffMap::new(3, 64, -1.0, 1).is_err());
        assert!(RffMap::new(0, 64, 1.0, 1).is_err());
        let mut st = RffKpca::new(3, 64, 6, 1.5, 1, true).unwrap();
        assert!(st.push(&[1.0, 2.0]).is_err(), "wrong dim must error");
        assert!(st.push_batch(&[1.0, 2.0]).is_err(), "ragged batch must error");
        assert_eq!(st.len(), 0);
    }
}
