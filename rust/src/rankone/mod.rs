//! Rank-one modification of the symmetric eigenproblem
//! (Bunch–Nielsen–Sorensen 1978), the engine under both of the paper's
//! incremental algorithms (§3.2):
//!
//! given `A = U Λ Uᵀ`, compute the eigendecomposition of `A + σ v vᵀ` as
//! `U Ũ Λ̃ Ũᵀ Uᵀ` where `Λ̃` solves the secular equation over `z = Uᵀv`
//! and the columns of `Ũ` are `Dᵢ⁻¹z / ‖Dᵢ⁻¹z‖`, `Dᵢ = Λ − λ̃ᵢI`
//! (paper eq. 6).
//!
//! The `2n³`-flop back-rotation `U · Ũ` dominates and is delegated to a
//! pluggable [`Rotate`] engine: the native blocked GEMM, or a PJRT
//! executable AOT-compiled from the Pallas kernel (see `runtime`).

use crate::linalg::{gemv_t, norm2, Mat};
use crate::secular::{deflate, solve_all, SecularRoot};

/// Engine for the `U_active · W` product — the hot `2n³` path.
pub trait Rotate {
    /// Multiply `u` (`m × k`) by `w` (`k × k`).
    fn rotate(&self, u: &Mat, w: &Mat) -> Mat;

    /// Fused path: given the raw secular quantities, build the
    /// normalized `W` internally and return `U·W` — the shape the AOT
    /// Pallas artifact implements (runtime::PjrtRotate). Returning
    /// `None` (default) makes `rank_one_update` build `W` in
    /// pole-relative precision and call [`Rotate::rotate`].
    fn rotate_fused(
        &self,
        _u: &Mat,
        _z: &[f64],
        _d: &[f64],
        _roots: &[SecularRoot],
    ) -> Option<Mat> {
        None
    }

    /// Short engine label for metrics/logs.
    fn name(&self) -> &'static str {
        "unnamed"
    }
}

/// Native engine: the in-tree blocked, parallel GEMM.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeRotate;

impl Rotate for NativeRotate {
    fn rotate(&self, u: &Mat, w: &Mat) -> Mat {
        crate::linalg::matmul(u, w)
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Diagnostics accumulated across updates (reported by §5.1-style
/// experiments and the coordinator's metrics endpoint).
#[derive(Clone, Copy, Debug, Default)]
pub struct UpdateStats {
    /// Eigenpairs that passed through unchanged (tiny weight).
    pub deflated: usize,
    /// Givens rotations applied for (near-)repeated eigenvalues.
    pub rotations: usize,
    /// Secular roots solved.
    pub solved: usize,
}

/// Relative deflation tolerance (on `|z|/‖z‖` and eigenvalue gaps).
pub const DEFAULT_DEFLATE_TOL: f64 = 1e-14;

/// Update the eigendecomposition `(vals ascending, vecs columns)` of a
/// symmetric matrix under the perturbation `+ σ v vᵀ`, in place.
///
/// `vecs` is `m × n` with one column per eigenpair (for full
/// decompositions `m == n`; the Hoegaerts top-k baseline uses `n < m`).
pub fn rank_one_update(
    vals: &mut Vec<f64>,
    vecs: &mut Mat,
    sigma: f64,
    v: &[f64],
    engine: &dyn Rotate,
) -> Result<UpdateStats, String> {
    rank_one_update_tol(vals, vecs, sigma, v, engine, DEFAULT_DEFLATE_TOL)
}

/// [`rank_one_update`] with an explicit deflation tolerance.
pub fn rank_one_update_tol(
    vals: &mut Vec<f64>,
    vecs: &mut Mat,
    sigma: f64,
    v: &[f64],
    engine: &dyn Rotate,
    tol: f64,
) -> Result<UpdateStats, String> {
    let n = vals.len();
    assert_eq!(vecs.cols(), n, "one eigenvector column per eigenvalue");
    assert_eq!(vecs.rows(), v.len(), "v must live in the row space of vecs");
    if n == 0 || sigma == 0.0 {
        return Ok(UpdateStats::default());
    }
    debug_assert!(
        vals.windows(2).all(|w| w[0] <= w[1]),
        "eigenvalues must be ascending"
    );

    // z = Uᵀ v — project the perturbation into the eigenbasis.
    let mut z = gemv_t(vecs, v);

    // Deflate tiny weights / repeated eigenvalues (rotating U with z).
    let def = deflate(vals, &mut z, Some(vecs), tol);
    let k = def.active.len();
    let stats = UpdateStats { deflated: def.deflated.len(), rotations: def.rotations, solved: k };
    if k == 0 {
        return Ok(stats);
    }

    // Secular solve on the active sub-problem.
    let roots = solve_all(&def.d_active, &def.z_active, sigma)?;

    // Gu–Eisenstat (1994) stabilization: recompute the weight vector ẑ
    // from the solved roots via the characteristic-polynomial identity,
    // so the eigenvector formula below is *exactly* consistent with the
    // computed eigenvalues. Without this, clustered poles (fast-decaying
    // kernel spectra) lose eigenvector orthogonality — the instability
    // the paper's §3 cites Gu & Eisenstat for.
    let z_hat = stabilized_weights(&def.d_active, &def.z_active, sigma, &roots);

    // Gather U_active (m × k). Fast path: with nothing deflated the
    // active set is the whole basis — rotate `vecs` in place and skip
    // both O(mk) copies (measured ~15% of the update at m=256, §Perf).
    let m = vecs.rows();
    let full = def.deflated.is_empty() && def.active.len() == vecs.cols();
    let u_active = if full {
        std::mem::replace(vecs, Mat::zeros(0, 0))
    } else {
        let mut u = Mat::zeros(m, k);
        for (c, &idx) in def.active.iter().enumerate() {
            for r in 0..m {
                u[(r, c)] = vecs[(r, idx)];
            }
        }
        u
    };

    // Back-rotation: either the engine's fused path (AOT Pallas kernel
    // building W on-device) or the native path, which assembles W here
    // in pole-relative precision — eigenvectors of the inner problem are
    // Ũ[:,i] = D̃ᵢ⁻¹ z / ‖·‖ over active coordinates (paper eq. 6) —
    // and issues one engine GEMM for the 2mk² product.
    let rotated = match engine.rotate_fused(&u_active, &z_hat, &def.d_active, &roots) {
        Some(r) => r,
        None => {
            let mut w = Mat::zeros(k, k);
            for (i, root) in roots.iter().enumerate() {
                let mut col = vec![0.0; k];
                for j in 0..k {
                    col[j] = z_hat[j] / root.diff(&def.d_active, j);
                }
                let nrm = norm2(&col);
                if nrm == 0.0 || !nrm.is_finite() {
                    return Err(format!("rank_one_update: degenerate eigenvector at root {i}"));
                }
                for j in 0..k {
                    w[(j, i)] = col[j] / nrm;
                }
            }
            engine.rotate(&u_active, &w)
        }
    };
    if full {
        // Roots are already ascending and cover every position.
        for (c, root) in roots.iter().enumerate() {
            vals[c] = root.value;
        }
        *vecs = rotated;
        return Ok(stats);
    }
    for (c, &idx) in def.active.iter().enumerate() {
        vals[idx] = roots[c].value;
        for r in 0..m {
            vecs[(r, idx)] = rotated[(r, c)];
        }
    }

    // Restore the ascending invariant (deflated values may now be out of
    // order relative to moved roots).
    sort_pairs(vals, vecs);
    Ok(stats)
}

/// Gu–Eisenstat weight recomputation: given sorted poles `d`, original
/// weights `z` (signs only), strength `sigma` and the solved roots,
/// return `ẑ` with `ẑⱼ² = ∏ᵢ(λ̃ᵢ − dⱼ) / (σ ∏_{i≠j}(dᵢ − dⱼ))`,
/// evaluated in interlacing-paired form so every factor is an `O(1)`
/// ratio (no overflow for large `n`). All differences `λ̃ᵢ − dⱼ` are
/// formed pole-relatively through [`SecularRoot::diff`].
fn stabilized_weights(
    d: &[f64],
    z: &[f64],
    sigma: f64,
    roots: &[crate::secular::SecularRoot],
) -> Vec<f64> {
    let n = d.len();
    let mut zhat = vec![0.0; n];
    for j in 0..n {
        let mut prod: f64;
        if sigma > 0.0 {
            // Interlacing: dᵢ < λ̃ᵢ < dᵢ₊₁, λ̃ₙ₋₁ < dₙ₋₁ + σ‖z‖².
            prod = -roots[n - 1].diff(d, j); // λ̃ₙ₋₁ − dⱼ > 0
            for i in 0..j {
                prod *= roots[i].diff(d, j) / (d[j] - d[i]); // (dⱼ−λ̃ᵢ)/(dⱼ−dᵢ)
            }
            for i in j..n - 1 {
                prod *= -roots[i].diff(d, j) / (d[i + 1] - d[j]); // (λ̃ᵢ−dⱼ)/(dᵢ₊₁−dⱼ)
            }
            prod /= sigma;
        } else {
            // Interlacing: dᵢ₋₁ < λ̃ᵢ < dᵢ, λ̃₀ > d₀ + σ‖z‖².
            prod = roots[0].diff(d, j); // dⱼ − λ̃₀ > 0
            for i in 1..=j {
                prod *= roots[i].diff(d, j) / (d[j] - d[i - 1]); // (dⱼ−λ̃ᵢ)/(dⱼ−dᵢ₋₁)
            }
            for i in (j + 1)..n {
                prod *= -roots[i].diff(d, j) / (d[i] - d[j]); // (λ̃ᵢ−dⱼ)/(dᵢ−dⱼ)
            }
            prod /= -sigma;
        }
        // Rounding can push a should-be-nonnegative product slightly
        // negative near exact deflation; clamp and fall back to the
        // original weight magnitude when degenerate.
        if prod.is_finite() && prod > 0.0 {
            zhat[j] = prod.sqrt().copysign(z[j]);
        } else {
            zhat[j] = z[j];
        }
    }
    zhat
}

/// Expand an eigensystem with a new decoupled eigenpair
/// `(new_val, eₘ₊₁)` — the paper's expansion step before the two
/// rank-one updates (Algorithm 1 lines 1–2 / Algorithm 2 lines 13–14),
/// then restore ascending order as eq. (5)'s note requires.
pub fn expand_eigensystem(vals: &mut Vec<f64>, vecs: &mut Mat, new_val: f64) {
    let m = vecs.rows();
    let n = vecs.cols();
    debug_assert_eq!(vals.len(), n);
    let mut grown = Mat::zeros(m + 1, n + 1);
    for i in 0..m {
        for j in 0..n {
            grown[(i, j)] = vecs[(i, j)];
        }
    }
    grown[(m, n)] = 1.0;
    *vecs = grown;
    vals.push(new_val);
    sort_pairs(vals, vecs);
}

/// Sort eigenpairs ascending, permuting columns alongside values.
pub fn sort_pairs(vals: &mut [f64], vecs: &mut Mat) {
    let n = vals.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
    if idx.iter().enumerate().all(|(i, &j)| i == j) {
        return;
    }
    let vals_old = vals.to_vec();
    let vecs_old = vecs.clone();
    for (newj, &oldj) in idx.iter().enumerate() {
        vals[newj] = vals_old[oldj];
        for i in 0..vecs.rows() {
            vecs[(i, newj)] = vecs_old[(i, oldj)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{eigh, matmul, orthogonality_defect};
    use crate::util::Rng;

    fn rand_sym(n: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.range(-1.0, 1.0);
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    fn check_update(n: usize, sigma: f64, seed: u64, tol: f64) {
        let mut rng = Rng::new(seed);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let mut vals = eg.values.clone();
        let mut vecs = eg.vectors.clone();
        let v: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        rank_one_update(&mut vals, &mut vecs, sigma, &v, &NativeRotate).unwrap();
        // Reference: dense eigendecomposition of A + σvvᵀ.
        let mut b = a.clone();
        b.syr(sigma, &v);
        let expect = eigh(&b).unwrap();
        for (u, w) in vals.iter().zip(expect.values.iter()) {
            assert!((u - w).abs() < tol, "n={n} sigma={sigma}: {u} vs {w}");
        }
        // Reconstruction check (eigenvector quality).
        let rec = {
            let mut vl = vecs.clone();
            for i in 0..n {
                for j in 0..n {
                    vl[(i, j)] *= vals[j];
                }
            }
            crate::linalg::matmul_nt(&vl, &vecs)
        };
        assert!(rec.max_abs_diff(&b) < tol * 10.0, "reconstruction n={n}");
        assert!(orthogonality_defect(&vecs) < 1e-10);
    }

    #[test]
    fn update_matches_dense_small() {
        check_update(4, 1.0, 1, 1e-9);
        check_update(4, -0.5, 2, 1e-9);
    }

    #[test]
    fn update_matches_dense_medium() {
        check_update(24, 2.0, 3, 1e-8);
        check_update(24, -1.3, 4, 1e-8);
    }

    #[test]
    fn repeated_updates_stay_orthogonal() {
        let n = 16;
        let mut rng = Rng::new(9);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let (mut vals, mut vecs) = (eg.values, eg.vectors);
        for _ in 0..50 {
            let v: Vec<f64> = (0..n).map(|_| rng.range(-0.5, 0.5)).collect();
            let sigma = rng.range(0.2, 1.0);
            rank_one_update(&mut vals, &mut vecs, sigma, &v, &NativeRotate).unwrap();
        }
        assert!(orthogonality_defect(&vecs) < 1e-8);
        for w in vals.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn deflation_fires_on_aligned_perturbation() {
        // v equal to an existing eigenvector: z has one nonzero entry →
        // n−1 deflations, eigenvalue shifts by exactly σ.
        let n = 6;
        let mut rng = Rng::new(5);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let (mut vals, mut vecs) = (eg.values.clone(), eg.vectors.clone());
        let v = eg.vectors.col(2);
        let stats = rank_one_update(&mut vals, &mut vecs, 0.7, &v, &NativeRotate).unwrap();
        assert_eq!(stats.deflated, n - 1);
        let mut expect = eg.values.clone();
        expect[2] += 0.7;
        expect.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (u, w) in vals.iter().zip(expect.iter()) {
            assert!((u - w).abs() < 1e-12);
        }
    }

    #[test]
    fn expand_inserts_sorted() {
        let mut vals = vec![1.0, 3.0];
        let mut vecs = Mat::eye(2);
        expand_eigensystem(&mut vals, &mut vecs, 2.0);
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
        assert_eq!(vecs.rows(), 3);
        // The new eigenvector e₃ must sit at the sorted position (col 1).
        assert_eq!(vecs[(2, 1)], 1.0);
        assert!(orthogonality_defect(&vecs) < 1e-15);
    }

    #[test]
    fn zero_sigma_is_noop() {
        let mut vals = vec![1.0, 2.0];
        let mut vecs = Mat::eye(2);
        let before = vecs.clone();
        rank_one_update(&mut vals, &mut vecs, 0.0, &[0.3, 0.4], &NativeRotate).unwrap();
        assert_eq!(vals, vec![1.0, 2.0]);
        assert_eq!(vecs.max_abs_diff(&before), 0.0);
    }

    #[test]
    fn property_random_updates_match_dense() {
        crate::util::prop::check("rankone-matches-dense", 16, |rng| {
            let n = 2 + rng.below(12);
            let a = rand_sym(n, rng);
            let eg = eigh(&a).map_err(|e| e.to_string())?;
            let (mut vals, mut vecs) = (eg.values, eg.vectors);
            let v: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
            let sigma = rng.range(-2.0, 2.0);
            rank_one_update(&mut vals, &mut vecs, sigma, &v, &NativeRotate)
                .map_err(|e| e.to_string())?;
            let mut b = a.clone();
            b.syr(sigma, &v);
            let expect = eigh(&b).map_err(|e| e.to_string())?;
            for (u, w) in vals.iter().zip(expect.values.iter()) {
                crate::util::prop::close("eigenvalue", *u, *w, 1e-7)?;
            }
            crate::util::prop::ensure(orthogonality_defect(&vecs) < 1e-8, || {
                format!("orthogonality defect {}", orthogonality_defect(&vecs))
            })
        });
    }

    #[test]
    fn interlacing_property_after_update() {
        crate::util::prop::check("rankone-interlacing", 12, |rng| {
            let n = 3 + rng.below(8);
            let a = rand_sym(n, rng);
            let eg = eigh(&a).map_err(|e| e.to_string())?;
            let old = eg.values.clone();
            let (mut vals, mut vecs) = (eg.values, eg.vectors);
            let v: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
            let sigma = rng.range(0.1, 2.0);
            rank_one_update(&mut vals, &mut vecs, sigma, &v, &NativeRotate)
                .map_err(|e| e.to_string())?;
            // λᵢ ≤ λ̃ᵢ ≤ λᵢ₊₁ for σ > 0 (paper eq. 5).
            for i in 0..n {
                crate::util::prop::ensure(vals[i] >= old[i] - 1e-9, || {
                    format!("lower interlace violated at {i}")
                })?;
                if i + 1 < n {
                    crate::util::prop::ensure(vals[i] <= old[i + 1] + 1e-9, || {
                        format!("upper interlace violated at {i}")
                    })?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rotate_engine_receives_gathered_panels() {
        struct Spy(std::sync::atomic::AtomicUsize);
        impl Rotate for Spy {
            fn rotate(&self, u: &Mat, w: &Mat) -> Mat {
                self.0.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                matmul(u, w)
            }
        }
        let spy = Spy(std::sync::atomic::AtomicUsize::new(0));
        let mut rng = Rng::new(31);
        let a = rand_sym(8, &mut rng);
        let eg = eigh(&a).unwrap();
        let (mut vals, mut vecs) = (eg.values, eg.vectors);
        let v: Vec<f64> = (0..8).map(|_| rng.range(-1.0, 1.0)).collect();
        rank_one_update(&mut vals, &mut vecs, 1.0, &v, &spy).unwrap();
        assert_eq!(spy.0.load(std::sync::atomic::Ordering::Relaxed), 1);
    }
}
