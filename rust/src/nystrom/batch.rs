//! Batch Nyström approximation (§2.4):
//! `K̃ = K_{n,m} K_{m,m}⁻¹ K_{m,n}`, equivalently the eigen-rescaled
//! form of eq. (7) — both implemented, and tested to agree, since the
//! incremental algorithm reproduces the latter.

use crate::kernels::{cross_gram, gram, Kernel};
use crate::linalg::{eigh, matmul, matmul_nt, Mat};

/// Batch Nyström approximation from an explicit subset.
#[derive(Clone, Debug)]
pub struct BatchNystrom {
    /// `n × m` cross-Gram between all points and the subset.
    pub knm: Mat,
    /// Eigenvalues of `K_{m,m}`, ascending.
    pub values: Vec<f64>,
    /// Eigenvectors of `K_{m,m}`.
    pub vectors: Mat,
    /// Relative eigenvalue cutoff for the pseudo-inverse.
    pub rcond: f64,
}

impl BatchNystrom {
    /// Build from data `x` (`n` rows) and subset row indices `subset`.
    pub fn fit(kernel: &dyn Kernel, x: &Mat, subset: &[usize]) -> Result<Self, String> {
        let m = subset.len();
        let sub = Mat::from_fn(m, x.cols(), |i, j| x[(subset[i], j)]);
        let kmm = gram(kernel, &sub);
        let knm = cross_gram(kernel, x, &sub);
        let eg = eigh(&kmm)?;
        Ok(BatchNystrom { knm, values: eg.values, vectors: eg.vectors, rcond: 1e-12 })
    }

    /// Approximate eigenpairs of the full `K` per eq. (7):
    /// `Λⁿʸˢ = (n/m) Λ`, `Uⁿʸˢ = √(m/n) K_{n,m} U Λ⁻¹`.
    pub fn approx_eigs(&self) -> (Vec<f64>, Mat) {
        let n = self.knm.rows();
        let m = self.values.len();
        let (nf, mf) = (n as f64, m as f64);
        let lam_max = self.values.iter().fold(0.0_f64, |a, &b| a.max(b.abs()));
        let cutoff = self.rcond * lam_max;
        let vals_nys: Vec<f64> = self.values.iter().map(|l| l * nf / mf).collect();
        // U Λ⁻¹ with pseudo-inverse cutoff.
        let mut ulinv = self.vectors.clone();
        for j in 0..m {
            let l = self.values[j];
            let inv = if l.abs() > cutoff { 1.0 / l } else { 0.0 };
            for i in 0..m {
                ulinv[(i, j)] *= inv;
            }
        }
        let mut u_nys = matmul(&self.knm, &ulinv);
        u_nys.scale((mf / nf).sqrt());
        (vals_nys, u_nys)
    }

    /// The approximation `K̃ = Uⁿʸˢ Λⁿʸˢ Uⁿʸˢᵀ  (= K_{n,m} K⁺_{m,m} K_{m,n})`.
    pub fn approx_gram(&self) -> Mat {
        let (vals, u) = self.approx_eigs();
        let n = u.rows();
        let m = u.cols();
        let mut ul = u.clone();
        for i in 0..n {
            for j in 0..m {
                ul[(i, j)] *= vals[j];
            }
        }
        matmul_nt(&ul, &u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::Rbf;
    use crate::linalg::Cholesky;

    #[test]
    fn matches_direct_inverse_formula() {
        let ds = yeast_like(30, 1);
        let kern = Rbf { sigma: 1.0 };
        let subset: Vec<usize> = (0..10).collect();
        let nys = BatchNystrom::fit(&kern, &ds.x, &subset).unwrap();
        // Direct: K_{n,m} K_{m,m}⁻¹ K_{m,n} via Cholesky.
        let sub = ds.x.submatrix(10, ds.dim());
        let kmm = crate::kernels::gram(&kern, &sub);
        let mut kmm_reg = kmm.clone();
        for i in 0..10 {
            kmm_reg[(i, i)] += 1e-12;
        }
        let ch = Cholesky::new(&kmm_reg).unwrap();
        let inv = ch.inverse();
        let direct = matmul(&matmul(&nys.knm, &inv), &nys.knm.transpose());
        assert!(nys.approx_gram().max_abs_diff(&direct) < 1e-6);
    }

    #[test]
    fn full_subset_reproduces_k_exactly() {
        let ds = yeast_like(12, 2);
        let kern = Rbf { sigma: 1.0 };
        let subset: Vec<usize> = (0..12).collect();
        let nys = BatchNystrom::fit(&kern, &ds.x, &subset).unwrap();
        let k = crate::kernels::gram(&kern, &ds.x);
        assert!(nys.approx_gram().max_abs_diff(&k) < 1e-8);
    }

    #[test]
    fn approximation_error_decreases_with_subset_size() {
        let ds = yeast_like(40, 3);
        let kern = Rbf { sigma: 1.0 };
        let k = crate::kernels::gram(&kern, &ds.x);
        let err = |m: usize| {
            let subset: Vec<usize> = (0..m).collect();
            let nys = BatchNystrom::fit(&kern, &ds.x, &subset).unwrap();
            crate::linalg::frobenius(&k.sub(&nys.approx_gram()))
        };
        let (e5, e20, e35) = (err(5), err(20), err(35));
        assert!(e20 < e5, "{e20} !< {e5}");
        assert!(e35 < e20, "{e35} !< {e20}");
    }

    #[test]
    fn psd_approximation() {
        let ds = yeast_like(20, 4);
        let kern = Rbf { sigma: 0.8 };
        let subset: Vec<usize> = (0..7).collect();
        let nys = BatchNystrom::fit(&kern, &ds.x, &subset).unwrap();
        let vals = crate::linalg::eigvalsh(&nys.approx_gram()).unwrap();
        assert!(vals[0] > -1e-9);
    }
}
