//! Chin & Suter (2007) incremental kernel PCA, kernelized from the
//! Lim et al. (2004) incremental SVD it builds on: each new example is
//! split into its projection onto the current centered feature basis
//! and an orthogonal residual; the mean shift contributes an extra
//! rank-one term; a *small* augmented eigenproblem is solved and the
//! coefficient matrix is rotated back — one `(m+1)×(r+1)` GEMM.
//!
//! Per the paper's §3 flop accounting this algorithm costs ≈`20m³` per
//! step: `9m³` for the eigendecomposition of the unadjusted kernel
//! matrix (basis re-orthonormalization in the original formulation),
//! `9m³` for the augmented small eigenproblem and `2m³` for the
//! rotation. Our kernelized variant only *needs* the latter two
//! (≈`11m³`); `faithful_cost: true` (default) also performs the
//! re-orthonormalization eigendecomposition so measured timings match
//! the paper's accounting of the original algorithm. The T1 ablation
//! flips it off.

use crate::kernels::{kernel_column_into, Kernel};
use crate::linalg::{eigh, matmul, Mat};

/// Chin–Suter incremental KPCA state (mean-adjusted, exact).
#[derive(Clone)]
pub struct ChinSuterKpca<'k> {
    kernel: &'k dyn Kernel,
    /// Retained examples (`m × dim` row-major).
    x: Vec<f64>,
    dim: usize,
    m: usize,
    /// Eigenvalues of `K'` above `rank_tol`, ascending.
    pub vals: Vec<f64>,
    /// Matching eigenvectors (`m × r`).
    pub vecs: Mat,
    /// Unadjusted kernel matrix (CS07 keeps it; `O(m²)` memory).
    k: Mat,
    /// Running row sums and total of the unadjusted kernel matrix.
    k1: Vec<f64>,
    s: f64,
    /// Eigenvalue cutoff defining the tracked rank.
    pub rank_tol: f64,
    /// Perform the basis re-orthonormalization eigendecomposition the
    /// original algorithm requires (cost parity with the paper's 20m³).
    pub faithful_cost: bool,
}

impl<'k> ChinSuterKpca<'k> {
    /// Initialize from a batch fit over `x0` (≥ 2 rows).
    pub fn from_batch(kernel: &'k dyn Kernel, x0: &Mat) -> Result<Self, String> {
        let m = x0.rows();
        if m < 2 {
            return Err("chin-suter needs ≥ 2 seed points".into());
        }
        let k = crate::kernels::gram(kernel, x0);
        let kc = crate::kpca::center_gram(&k);
        let eg = eigh(&kc)?;
        let rank_tol = 1e-10;
        // Keep only the numerically nonzero part of the spectrum.
        let scale = eg.values.iter().fold(0.0_f64, |a, &b| a.max(b.abs()));
        let first = eg.values.iter().position(|&l| l > rank_tol * scale.max(1.0)).unwrap_or(m);
        let r = m - first;
        let mut vecs = Mat::zeros(m, r);
        let mut vals = Vec::with_capacity(r);
        for (c, j) in (first..m).enumerate() {
            vals.push(eg.values[j]);
            for i in 0..m {
                vecs[(i, c)] = eg.vectors[(i, j)];
            }
        }
        let k1: Vec<f64> = (0..m).map(|i| k.row(i).iter().sum()).collect();
        let s = k1.iter().sum();
        Ok(ChinSuterKpca {
            kernel,
            x: x0.as_slice().to_vec(),
            dim: x0.cols(),
            m,
            vals,
            vecs,
            k,
            k1,
            s,
            rank_tol,
            faithful_cost: true,
        })
    }

    pub fn len(&self) -> usize {
        self.m
    }

    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    /// Tracked rank.
    pub fn rank(&self) -> usize {
        self.vals.len()
    }

    /// Ingest one example (exact mean-adjusted update).
    pub fn push(&mut self, xnew: &[f64]) -> Result<(), String> {
        assert_eq!(xnew.len(), self.dim);
        let m = self.m;
        let mf = m as f64;
        let r = self.rank();
        // Kernel column over the flat retained data — no matrix clone.
        let mut a = Vec::with_capacity(m);
        kernel_column_into(self.kernel, &self.x, self.dim, m, xnew, &mut a);
        let knew = self.kernel.eval(xnew, xnew);
        let asum: f64 = a.iter().sum();

        if self.faithful_cost {
            // CS07's feature basis is non-orthogonal (spanned by raw
            // feature vectors); the original algorithm re-orthonormalizes
            // through an eigendecomposition of the unadjusted kernel
            // matrix. Our coordinates never leave the orthonormal
            // eigenbasis, so the result is unused — but the cost is real
            // in the original method and is charged here for parity.
            let _ = eigh(&self.k)?;
        }

        // Centered coordinates of the new point w.r.t. the current mean:
        // ⟨φ(xᵢ)−μₘ, φ(x)−μₘ⟩ and ‖φ(x)−μₘ‖².
        let atil: Vec<f64> = (0..m)
            .map(|i| a[i] - self.k1[i] / mf - asum / mf + self.s / (mf * mf))
            .collect();
        let q = knew - 2.0 * asum / mf + self.s / (mf * mf);

        // Projection p onto the r orthonormal basis directions
        // (bᵢ = Φ'ᵀuᵢ/√λᵢ) and the orthogonal residual ρ.
        let mut p = vec![0.0; r];
        for j in 0..r {
            let mut dot = 0.0;
            for i in 0..m {
                dot += self.vecs[(i, j)] * atil[i];
            }
            p[j] = dot / self.vals[j].sqrt();
        }
        let rho2 = q - p.iter().map(|v| v * v).sum::<f64>();
        let rho = rho2.max(0.0).sqrt();

        // Coordinates of the re-centered data rows in the augmented
        // basis [b₁…b_r, e_⊥]:  C = C₀ + w hᵀ, with C₀ the block-diag
        // scaled eigenvector matrix, w the mean-shift pattern and
        // h = [p; ρ].
        let mut c0 = Mat::zeros(m + 1, r + 1);
        for i in 0..m {
            for j in 0..r {
                c0[(i, j)] = self.vecs[(i, j)] * self.vals[j].sqrt();
            }
        }
        let mut h = p.clone();
        h.push(rho);
        let m1f = mf + 1.0;
        let mut c = c0;
        for i in 0..m {
            for j in 0..r + 1 {
                c[(i, j)] -= h[j] / m1f;
            }
        }
        for j in 0..r + 1 {
            c[(m, j)] += h[j] * mf / m1f;
        }

        // Augmented small problem: G = CᵀC, eigendecomposed.
        let g = matmul(&c.transpose(), &c);
        let eg = eigh(&g)?;

        // New eigenpairs: Λ = D (above cutoff), U = C Q D^{-1/2} — the
        // (m+1)×(r+1) rotation GEMM that dominates at ≈2m³ flops.
        let scale = eg.values.iter().fold(0.0_f64, |acc, &b| acc.max(b.abs()));
        let keep: Vec<usize> = (0..eg.values.len())
            .filter(|&j| eg.values[j] > self.rank_tol * scale.max(1.0))
            .collect();
        let mut q_keep = Mat::zeros(r + 1, keep.len());
        for (cj, &j) in keep.iter().enumerate() {
            for i in 0..r + 1 {
                q_keep[(i, cj)] = eg.vectors[(i, j)];
            }
        }
        let mut u_new = matmul(&c, &q_keep);
        let mut vals_new = Vec::with_capacity(keep.len());
        for (cj, &j) in keep.iter().enumerate() {
            let d = eg.values[j];
            vals_new.push(d);
            let inv = 1.0 / d.sqrt();
            for i in 0..m + 1 {
                u_new[(i, cj)] *= inv;
            }
        }

        // Commit: eigensystem, kernel matrix, running sums, data.
        self.vals = vals_new;
        self.vecs = u_new;
        let mut k_grown = Mat::zeros(m + 1, m + 1);
        for i in 0..m {
            for j in 0..m {
                k_grown[(i, j)] = self.k[(i, j)];
            }
            k_grown[(i, m)] = a[i];
            k_grown[(m, i)] = a[i];
        }
        k_grown[(m, m)] = knew;
        self.k = k_grown;
        for (k1i, ai) in self.k1.iter_mut().zip(&a) {
            *k1i += ai;
        }
        self.k1.push(asum + knew);
        self.s += 2.0 * asum + knew;
        self.x.extend_from_slice(xnew);
        self.m += 1;
        Ok(())
    }

    /// Reconstruction `U Λ Uᵀ` of the centered kernel matrix.
    pub fn reconstruct(&self) -> Mat {
        let (m, r) = (self.m, self.rank());
        let mut ul = self.vecs.clone();
        for i in 0..m {
            for j in 0..r {
                ul[(i, j)] *= self.vals[j];
            }
        }
        crate::linalg::matmul_nt(&ul, &self.vecs)
    }

    /// Batch ground truth of the centered kernel matrix.
    pub fn batch_reference(&self) -> Mat {
        let xmat = Mat::from_vec(self.m, self.dim, self.x.clone());
        let k = crate::kernels::gram(self.kernel, &xmat);
        crate::kpca::center_gram(&k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::Rbf;

    #[test]
    fn exact_against_batch() {
        let ds = yeast_like(18, 1);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(5, ds.dim());
        let mut cs = ChinSuterKpca::from_batch(&kern, &seed).unwrap();
        cs.faithful_cost = false; // speed: result identical either way
        for i in 5..ds.n() {
            cs.push(ds.x.row(i)).unwrap();
        }
        let drift = cs.reconstruct().max_abs_diff(&cs.batch_reference());
        assert!(drift < 1e-8, "drift {drift}");
    }

    #[test]
    fn rank_stays_below_m() {
        // The centered Gram has rank ≤ m−1; the tracked rank must too.
        let ds = yeast_like(12, 2);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut cs = ChinSuterKpca::from_batch(&kern, &seed).unwrap();
        cs.faithful_cost = false;
        for i in 4..ds.n() {
            cs.push(ds.x.row(i)).unwrap();
            assert!(cs.rank() < cs.len(), "rank {} vs m {}", cs.rank(), cs.len());
        }
    }

    #[test]
    fn agrees_with_papers_incremental() {
        let ds = yeast_like(14, 3);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(6, ds.dim());
        let mut cs = ChinSuterKpca::from_batch(&kern, &seed).unwrap();
        cs.faithful_cost = false;
        let mut ours = crate::kpca::IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 6..ds.n() {
            cs.push(ds.x.row(i)).unwrap();
            ours.push(ds.x.row(i)).unwrap();
        }
        // Same matrix reconstructed by both exact algorithms.
        let diff = cs.reconstruct().max_abs_diff(&ours.reconstruct());
        assert!(diff < 1e-7, "CS vs ours diff {diff}");
    }

    #[test]
    fn needs_two_seed_points() {
        let kern = Rbf { sigma: 1.0 };
        assert!(ChinSuterKpca::from_batch(&kern, &Mat::zeros(1, 4)).is_err());
    }
}
