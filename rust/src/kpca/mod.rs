//! Kernel PCA: the batch baseline (§2.2), the feature-space centering
//! transform (eq. 1), the paper's incremental Algorithms 1 & 2 (§3.1)
//! and component projection for scoring new points.

pub mod batch;
pub mod centering;
pub mod incremental;
pub mod krr;
pub mod projection;
pub mod topk;

pub use batch::BatchKpca;
pub use centering::{center_column, center_gram};
pub use incremental::{
    BatchOutcome, BatchRotation, EvictionPolicy, IncrementalKpca, KpcaParts, KpcaStats,
    LEV_REFRESH_EVERY,
};
pub use krr::IncrementalKrr;
pub use projection::project_point;
pub use topk::TopKKpca;
