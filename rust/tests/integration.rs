//! Cross-layer integration tests: artifacts → PJRT runtime → engine
//! router → coordinator → algorithms, on realistic small workloads.
//! PJRT-dependent tests no-op gracefully when `artifacts/` is absent
//! (run `make artifacts` first for full coverage).

use inkpca::coordinator::{
    Config, Coordinator, EngineConfig, EnginePolicy, KernelConfig,
};
use inkpca::data::synthetic::{magic_like, yeast_like};
use inkpca::data::SliceSource;
use inkpca::kernels::{gram, median_heuristic, Linear, Rbf};
use inkpca::kpca::{BatchKpca, IncrementalKpca};
use inkpca::linalg::{frobenius, Mat};
use inkpca::nystrom::{BatchNystrom, IncrementalNystrom};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.tsv").exists()
}

#[test]
fn full_stack_native_session() {
    let mut ds = yeast_like(60, 1);
    ds.standardize();
    let dim = ds.dim();
    let coord = Coordinator::spawn(
        Config { seed_points: 10, drift_every: 20, ..Config::default() },
        dim,
    );
    let mut src = SliceSource::new(ds);
    let accepted = coord.ingest_stream(&mut src).unwrap();
    assert_eq!(accepted, 60);
    let drift = coord.measure_drift().unwrap();
    assert!(drift.norms.frobenius < 1e-6, "native session drift {:?}", drift.norms);
    let m = coord.metrics().unwrap();
    assert_eq!(m.accepted, 50);
    assert_eq!(m.errors, 0);
    coord.shutdown();
}

#[test]
fn full_stack_pjrt_session() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts/");
        return;
    }
    let mut ds = magic_like(40, 2);
    ds.standardize();
    let dim = ds.dim();
    let coord = Coordinator::spawn(
        Config {
            engine: EngineConfig::Pjrt {
                dir: "artifacts".into(),
                policy: EnginePolicy::Pjrt,
            },
            seed_points: 10,
            drift_every: 0,
            ..Config::default()
        },
        dim,
    );
    for i in 0..ds.n() {
        coord.ingest(ds.x.row(i).to_vec()).unwrap();
    }
    let snap = coord.snapshot().unwrap();
    assert_eq!(snap.m, 40);
    assert!(snap.engine_calls.1 > 0, "pjrt engine never used: {:?}", snap.engine_calls);
    let drift = coord.measure_drift().unwrap();
    assert!(drift.norms.frobenius < 1e-6, "pjrt session drift {:?}", drift.norms);
    coord.shutdown();
}

#[test]
fn engine_equivalence_native_vs_pjrt() {
    // The same stream through both engines must produce (numerically)
    // the same eigensystem.
    if !have_artifacts() {
        return;
    }
    let rt = std::sync::Arc::new(
        inkpca::runtime::Runtime::new(std::path::Path::new("artifacts")).unwrap(),
    );
    let pjrt = inkpca::runtime::PjrtRotate::new(rt);
    let mut ds = yeast_like(30, 3);
    ds.standardize();
    let kern = Rbf { sigma: median_heuristic(&ds.x, 100) };
    let seed = ds.x.submatrix(8, ds.dim());
    let mut a = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    let mut b = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    for i in 8..ds.n() {
        a.push(ds.x.row(i)).unwrap();
        b.push_with(ds.x.row(i), &pjrt).unwrap();
    }
    for (x, y) in a.vals.iter().zip(b.vals.iter()) {
        assert!((x - y).abs() < 1e-8, "eigenvalue mismatch {x} vs {y}");
    }
    assert!(a.reconstruct().max_abs_diff(&b.reconstruct()) < 1e-7);
}

#[test]
fn fault_injection_mean_point_excluded_by_coordinator() {
    // The §5.1 exclusion path must surface through the whole stack
    // without corrupting the session.
    let ds = yeast_like(16, 4);
    let dim = ds.dim();
    let coord = Coordinator::spawn(
        Config {
            kernel: KernelConfig::Linear,
            seed_points: 16,
            ..Config::default()
        },
        dim,
    );
    for i in 0..16 {
        coord.ingest(ds.x.row(i).to_vec()).unwrap();
    }
    let mean: Vec<f64> =
        (0..dim).map(|j| (0..16).map(|i| ds.x[(i, j)]).sum::<f64>() / 16.0).collect();
    let reply = coord.ingest(mean).unwrap();
    assert!(!reply.accepted);
    let metrics = coord.metrics().unwrap();
    assert_eq!(metrics.excluded, 1);
    // Session continues normally.
    let reply = coord.ingest(vec![9.0; dim]).unwrap();
    assert!(reply.accepted);
    let drift = coord.measure_drift().unwrap();
    assert!(drift.norms.frobenius < 1e-6);
    coord.shutdown();
}

#[test]
fn nystrom_incremental_equals_batch_larger_scale() {
    let mut ds = magic_like(120, 5);
    ds.standardize();
    let kern = Rbf { sigma: median_heuristic(&ds.x, 120) };
    let mut inys = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
    for m in 0..40 {
        inys.add_point(m).unwrap();
    }
    let batch = BatchNystrom::fit(&kern, &ds.x, &(0..40).collect::<Vec<_>>()).unwrap();
    let diff = inys.approx_gram().max_abs_diff(&batch.approx_gram());
    assert!(diff < 1e-6, "incremental vs batch Nyström {diff}");
    // And the error actually shrinks vs the trivial zero approximation.
    let k = gram(&kern, &ds.x);
    let err = frobenius(&k.sub(&inys.approx_gram()));
    assert!(err < 0.5 * frobenius(&k));
}

#[test]
fn incremental_matches_batch_multiple_kernels() {
    let mut ds = yeast_like(26, 6);
    ds.standardize();
    let kernels: Vec<Box<dyn inkpca::kernels::Kernel>> = vec![
        Box::new(Rbf { sigma: 2.0 }),
        Box::new(Linear),
        Box::new(inkpca::kernels::Polynomial { degree: 2, offset: 1.0 }),
        Box::new(inkpca::kernels::Laplacian { sigma: 2.0 }),
    ];
    for kern in &kernels {
        let seed = ds.x.submatrix(8, ds.dim());
        let mut inc = IncrementalKpca::from_batch(kern.as_ref(), &seed, true).unwrap();
        for i in 8..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        let batch = BatchKpca::fit(kern.as_ref(), &ds.x, true).unwrap();
        let drift = inc.reconstruct().max_abs_diff(&batch.k_used);
        assert!(drift < 1e-6, "{}: drift {drift}", kern.name());
    }
}

#[test]
fn coordinator_backpressure_bounded_queue() {
    // A queue of 1 forces full rendezvous; the stream must still finish.
    let ds = yeast_like(20, 7);
    let coord = Coordinator::spawn(
        Config { queue: 1, seed_points: 5, ..Config::default() },
        ds.dim(),
    );
    for i in 0..20 {
        coord.ingest(ds.x.row(i).to_vec()).unwrap();
    }
    assert_eq!(coord.snapshot().unwrap().m, 20);
    coord.shutdown();
}

#[test]
fn runtime_bucket_padding_invariance() {
    // The same logical problem executed at two different bucket sizes
    // (just below and above a bucket edge) gives the same answer.
    if !have_artifacts() {
        return;
    }
    let rt = inkpca::runtime::Runtime::new(std::path::Path::new("artifacts")).unwrap();
    let mut rng = inkpca::util::Rng::new(8);
    for &m in &[63usize, 64, 65] {
        let x = Mat::from_fn(m, 10, |_, _| rng.range(-1.0, 1.0));
        let y: Vec<f64> = (0..10).map(|_| rng.range(-1.0, 1.0)).collect();
        let got = rt.kernel_column(&x, &y, 1.1).unwrap();
        let want = inkpca::kernels::kernel_column(&Rbf { sigma: 1.1 }, &x, m, &y);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-12, "m={m}");
        }
    }
}
