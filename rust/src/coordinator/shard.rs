//! Sharded multi-stream coordinator: a [`ShardPool`] of worker threads,
//! each owning a map of stream-id → per-stream state, fronted by a
//! stream-keyed [`StreamRouter`].
//!
//! # Design
//!
//! **Pinning.** Every stream id is hashed (FNV-1a, deterministic within
//! and across processes) and pinned to `hash % shards` for its whole
//! life. All commands for a stream therefore serialize through one
//! worker — per-stream state needs no locks, and the paper's rank-one
//! hot path (workspace + eigenbasis, allocation-free once warm, PR 1)
//! runs untouched inside the shard. Streams only ever contend with the
//! *other streams of their own shard*.
//!
//! **Backpressure.** Each shard has its own *bounded* command channel
//! (`PoolConfig::queue` deep). Producers of a hot shard block on that
//! shard's queue without slowing streams pinned elsewhere — the same
//! rendezvous discipline the single-stream coordinator used, sharded.
//!
//! **Shared immutable resources.** One [`RoutedEngine`] (and, when
//! configured, one PJRT runtime — it is not `Send`, so it must be built
//! inside the worker thread) exists *per shard*, not per stream: the
//! engine is stateless apart from its dispatch counters, so all streams
//! of a shard share it. Per-stream state owns its kernel through an
//! `Arc` handed to [`IncrementalKpca::from_batch_shared`] — closing a
//! stream frees its kernel (the old single-stream server `Box::leak`ed
//! one kernel per coordinator, which a multi-stream pool cannot afford).
//!
//! **Metrics aggregation.** Each stream entry keeps its own
//! [`Metrics`] (latency histograms + counters + hot-path gauges).
//! [`StreamRouter::pool_snapshot`] asks every shard for a rollup —
//! counters summed, histograms merged bucket-wise, engine dispatch
//! counts added — and returns one [`PoolSnapshot`] with the per-stream
//! [`StreamGauges`] attached for attribution.

use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::kernels::{median_heuristic, Kernel};
use crate::kpca::{IncrementalKpca, KpcaStats};
use crate::linalg::Mat;

use super::drift::{DriftMonitor, DriftPoint};
use super::metrics::{LatencyHistogram, Metrics, MetricsReport, PoolSnapshot, StreamGauges};
use super::router::RoutedEngine;
use super::server::{EngineConfig, IngestReply, KernelConfig, Snapshot};

/// Per-stream configuration (what used to be the per-coordinator
/// `Config`, minus the pool-level engine/queue knobs).
#[derive(Clone, Debug)]
pub struct StreamConfig {
    pub kernel: KernelConfig,
    pub mean_adjust: bool,
    /// Seed examples accumulated before the batch initialization.
    pub seed_points: usize,
    /// Drift measurement cadence (accepted points; 0 = off).
    pub drift_every: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            kernel: KernelConfig::RbfMedian,
            mean_adjust: true,
            seed_points: 20,
            drift_every: 0,
        }
    }
}

/// Pool-level configuration: shard/queue topology and the (per-shard)
/// rotation engine.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Worker threads; streams are pinned by stream-id hash.
    pub shards: usize,
    /// Bounded command-queue depth *per shard* (ingest backpressure).
    pub queue: usize,
    /// Rotation engine, instantiated once per shard worker.
    pub engine: EngineConfig,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { shards: 1, queue: 64, engine: EngineConfig::Native }
    }
}

enum ShardCommand {
    Open {
        stream: String,
        dim: usize,
        cfg: StreamConfig,
        reply: SyncSender<Result<(), String>>,
    },
    Ingest {
        stream: String,
        x: Vec<f64>,
        reply: SyncSender<Result<IngestReply, String>>,
    },
    Project {
        stream: String,
        x: Vec<f64>,
        r: usize,
        reply: SyncSender<Result<Vec<f64>, String>>,
    },
    MeasureDrift {
        stream: String,
        reply: SyncSender<Result<DriftPoint, String>>,
    },
    Snapshot {
        stream: String,
        reply: SyncSender<Result<Snapshot, String>>,
    },
    Metrics {
        stream: String,
        reply: SyncSender<Result<MetricsReport, String>>,
    },
    Close {
        stream: String,
        reply: SyncSender<Result<KpcaStats, String>>,
    },
    Rollup {
        reply: SyncSender<ShardRollup>,
    },
    Shutdown,
}

/// Per-shard aggregation answered to `Rollup` (internal wire format;
/// the router folds these into one [`PoolSnapshot`]).
struct ShardRollup {
    streams: usize,
    accepted: u64,
    excluded: u64,
    errors: u64,
    total_ws_bytes: u64,
    ingest: LatencyHistogram,
    project: LatencyHistogram,
    engine_calls: (u64, u64),
    gauges: Vec<StreamGauges>,
}

/// Lifetime totals of streams already closed on this shard: folded into
/// every rollup so pool-level counters stay *monotonic* across stream
/// churn (closing a stream must not erase its history from the pool).
/// Residency gauges are deliberately not kept — closed streams hold no
/// bytes.
#[derive(Default)]
struct ClosedTotals {
    accepted: u64,
    excluded: u64,
    errors: u64,
    ingest: LatencyHistogram,
    project: LatencyHistogram,
}

impl ClosedTotals {
    fn absorb(&mut self, m: &Metrics) {
        self.accepted += m.accepted;
        self.excluded += m.excluded;
        self.errors += m.errors;
        self.ingest.merge(&m.ingest_latency);
        self.project.merge(&m.project_latency);
    }
}

/// Build the kernel a stream entry owns (shared ownership — freed with
/// the stream, never leaked).
fn build_kernel(cfg: &KernelConfig, seed: &Mat) -> Arc<dyn Kernel> {
    match cfg {
        KernelConfig::Rbf { sigma } => Arc::new(crate::kernels::Rbf { sigma: *sigma }),
        KernelConfig::RbfMedian => {
            let sigma = median_heuristic(seed, 500);
            Arc::new(crate::kernels::Rbf { sigma })
        }
        KernelConfig::Linear => Arc::new(crate::kernels::Linear),
        KernelConfig::Polynomial { degree, offset } => {
            Arc::new(crate::kernels::Polynomial { degree: *degree, offset: *offset })
        }
        KernelConfig::Laplacian { sigma } => {
            Arc::new(crate::kernels::Laplacian { sigma: *sigma })
        }
    }
}

/// Build the shard's shared rotation engine. The PJRT runtime is not
/// `Send`, so this runs inside the worker thread — one runtime per
/// worker, shared by all streams pinned to it.
fn build_engine(cfg: &EngineConfig) -> RoutedEngine {
    match cfg {
        EngineConfig::Native => RoutedEngine::native_only(),
        EngineConfig::Pjrt { dir, policy } => {
            match crate::runtime::Runtime::new(std::path::Path::new(dir)) {
                Ok(rt) => RoutedEngine::with_pjrt(
                    crate::runtime::PjrtRotate::new(std::sync::Arc::new(rt)),
                    policy.clone(),
                ),
                Err(e) => {
                    eprintln!("shard: pjrt unavailable ({e}); using native engine");
                    RoutedEngine::native_only()
                }
            }
        }
    }
}

/// All state of one stream, owned by exactly one shard worker:
/// the incremental eigensystem (which itself owns the kernel, the
/// update workspace and the eigenbasis), the drift monitor, and the
/// per-stream metrics.
struct StreamEntry {
    cfg: StreamConfig,
    dim: usize,
    seed_buf: Vec<f64>,
    seeded: usize,
    state: Option<IncrementalKpca<'static>>,
    drift: DriftMonitor,
    metrics: Metrics,
}

impl StreamEntry {
    fn new(dim: usize, cfg: StreamConfig) -> StreamEntry {
        let drift = DriftMonitor::new(cfg.drift_every);
        StreamEntry {
            cfg,
            dim,
            seed_buf: Vec::new(),
            seeded: 0,
            state: None,
            drift,
            metrics: Metrics::default(),
        }
    }

    fn min_seed(&self) -> usize {
        if self.cfg.mean_adjust {
            self.cfg.seed_points.max(2)
        } else {
            self.cfg.seed_points.max(1)
        }
    }

    fn ingest(&mut self, x: Vec<f64>, engine: &RoutedEngine) -> Result<IngestReply, String> {
        if x.len() != self.dim {
            self.metrics.errors += 1;
            return Err(format!("dimension mismatch: got {}, want {}", x.len(), self.dim));
        }
        if self.state.is_none() {
            // Seeding phase: buffer until the batch init.
            self.seed_buf.extend_from_slice(&x);
            self.seeded += 1;
            if self.seeded < self.min_seed() {
                return Ok(IngestReply { accepted: true, m: self.seeded, seeding: true });
            }
            let seed = Mat::from_vec(self.seeded, self.dim, self.seed_buf.clone());
            let kernel = build_kernel(&self.cfg.kernel, &seed);
            return match IncrementalKpca::from_batch_shared(kernel, &seed, self.cfg.mean_adjust)
            {
                Ok(st) => {
                    // The batch init allocated the full eigensystem +
                    // workspace — publish the residency gauges now, not
                    // only after the first post-seed push.
                    self.metrics.updates = st.stats.updates as u64;
                    self.metrics.ws_bytes_resident = st.hot_path_bytes() as u64;
                    self.metrics.ws_reallocs = st.hot_path_reallocs();
                    self.state = Some(st);
                    Ok(IngestReply { accepted: true, m: self.seeded, seeding: false })
                }
                Err(e) => {
                    self.metrics.errors += 1;
                    Err(e)
                }
            };
        }
        let st = self.state.as_mut().unwrap();
        match st.push_with(&x, engine) {
            Ok(accepted) => {
                if accepted {
                    self.metrics.accepted += 1;
                    self.drift.on_accept(st);
                } else {
                    self.metrics.excluded += 1;
                }
                // Refresh the per-stream hot-path gauges.
                self.metrics.updates = st.stats.updates as u64;
                self.metrics.ws_bytes_resident = st.hot_path_bytes() as u64;
                self.metrics.ws_reallocs = st.hot_path_reallocs();
                Ok(IngestReply { accepted, m: st.len(), seeding: false })
            }
            Err(e) => {
                self.metrics.errors += 1;
                Err(e)
            }
        }
    }

    fn project(&self, x: &[f64], r: usize) -> Result<Vec<f64>, String> {
        match (&self.state, x.len() == self.dim) {
            (Some(st), true) => Ok(st.project(x, r)),
            (Some(_), false) => Err("dimension mismatch".to_string()),
            (None, _) => Err("not initialized (still seeding)".to_string()),
        }
    }

    fn measure_drift(&mut self) -> Result<DriftPoint, String> {
        match &self.state {
            Some(st) => Ok(self.drift.measure(st)),
            None => Err("not initialized".to_string()),
        }
    }

    fn snapshot(&self, engine_calls: (u64, u64)) -> Snapshot {
        match &self.state {
            Some(st) => Snapshot {
                m: st.len(),
                dim: self.dim,
                top_values: st.vals.iter().rev().take(10).copied().collect(),
                stats: st.stats,
                drift: self.drift.latest().copied(),
                engine_calls,
            },
            None => Snapshot {
                m: self.seeded,
                dim: self.dim,
                top_values: Vec::new(),
                stats: KpcaStats::default(),
                drift: None,
                engine_calls,
            },
        }
    }

    fn gauges(&self, stream: &str, shard: usize) -> StreamGauges {
        StreamGauges {
            stream: stream.to_string(),
            shard,
            m: self.state.as_ref().map(|s| s.len()).unwrap_or(self.seeded),
            ws_bytes_resident: self.metrics.ws_bytes_resident,
            ws_reallocs: self.metrics.ws_reallocs,
            reallocs_per_update: self.metrics.reallocs_per_update(),
            drift_frobenius: self.drift.latest().map(|d| d.norms.frobenius),
        }
    }

    fn final_stats(self) -> KpcaStats {
        self.state.map(|s| s.stats).unwrap_or_default()
    }
}

fn shard_worker(shard: usize, engine_cfg: EngineConfig, rx: Receiver<ShardCommand>) {
    let engine = build_engine(&engine_cfg);
    let mut streams: HashMap<String, StreamEntry> = HashMap::new();
    let mut closed = ClosedTotals::default();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            ShardCommand::Open { stream, dim, cfg, reply } => {
                let res = if streams.contains_key(&stream) {
                    Err(format!("stream '{stream}' already open"))
                } else {
                    streams.insert(stream, StreamEntry::new(dim, cfg));
                    Ok(())
                };
                let _ = reply.send(res);
            }
            ShardCommand::Ingest { stream, x, reply } => {
                let res = match streams.get_mut(&stream) {
                    Some(entry) => {
                        let t0 = Instant::now();
                        let r = entry.ingest(x, &engine);
                        entry.metrics.ingest_latency.record(t0.elapsed());
                        r
                    }
                    None => Err(format!("unknown stream '{stream}'")),
                };
                let _ = reply.send(res);
            }
            ShardCommand::Project { stream, x, r, reply } => {
                let res = match streams.get_mut(&stream) {
                    Some(entry) => {
                        let t0 = Instant::now();
                        let out = entry.project(&x, r);
                        entry.metrics.project_latency.record(t0.elapsed());
                        out
                    }
                    None => Err(format!("unknown stream '{stream}'")),
                };
                let _ = reply.send(res);
            }
            ShardCommand::MeasureDrift { stream, reply } => {
                let res = match streams.get_mut(&stream) {
                    Some(entry) => entry.measure_drift(),
                    None => Err(format!("unknown stream '{stream}'")),
                };
                let _ = reply.send(res);
            }
            ShardCommand::Snapshot { stream, reply } => {
                let res = match streams.get(&stream) {
                    Some(entry) => Ok(entry.snapshot(engine.counts())),
                    None => Err(format!("unknown stream '{stream}'")),
                };
                let _ = reply.send(res);
            }
            ShardCommand::Metrics { stream, reply } => {
                let res = match streams.get(&stream) {
                    Some(entry) => Ok(entry.metrics.report()),
                    None => Err(format!("unknown stream '{stream}'")),
                };
                let _ = reply.send(res);
            }
            ShardCommand::Close { stream, reply } => {
                let res = match streams.remove(&stream) {
                    Some(entry) => {
                        // Keep the stream's lifetime counters/latency in
                        // the shard totals — pool counters stay monotonic.
                        closed.absorb(&entry.metrics);
                        Ok(entry.final_stats())
                    }
                    None => Err(format!("unknown stream '{stream}'")),
                };
                let _ = reply.send(res);
            }
            ShardCommand::Rollup { reply } => {
                let mut rollup = ShardRollup {
                    streams: streams.len(),
                    accepted: closed.accepted,
                    excluded: closed.excluded,
                    errors: closed.errors,
                    total_ws_bytes: 0,
                    ingest: closed.ingest.clone(),
                    project: closed.project.clone(),
                    engine_calls: engine.counts(),
                    gauges: Vec::with_capacity(streams.len()),
                };
                for (name, entry) in &streams {
                    rollup.accepted += entry.metrics.accepted;
                    rollup.excluded += entry.metrics.excluded;
                    rollup.errors += entry.metrics.errors;
                    rollup.total_ws_bytes += entry.metrics.ws_bytes_resident;
                    rollup.ingest.merge(&entry.metrics.ingest_latency);
                    rollup.project.merge(&entry.metrics.project_latency);
                    rollup.gauges.push(entry.gauges(name, shard));
                }
                let _ = reply.send(rollup);
            }
            ShardCommand::Shutdown => break,
        }
    }
}

/// FNV-1a — deterministic stream→shard pinning (the std hasher is
/// randomly seeded per process, which would break cross-run
/// attribution in logs and tests).
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Cloneable, thread-safe routing front-end over the per-shard command
/// channels. `ingest`/`project`/`open_stream`/`close_stream` hash the
/// stream id to its pinned shard; producers on different shards never
/// touch the same queue.
#[derive(Clone)]
pub struct StreamRouter {
    shards: Arc<Vec<SyncSender<ShardCommand>>>,
}

impl StreamRouter {
    /// Number of shards behind this router.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard a stream id is pinned to (stable for the pool's life).
    pub fn shard_of(&self, stream: &str) -> usize {
        (fnv1a(stream) % self.shards.len() as u64) as usize
    }

    /// One rendezvous round-trip to shard `shard`: build the command
    /// around a fresh reply channel, send, await the answer. Every
    /// router verb goes through here so the error discipline cannot
    /// diverge between commands.
    fn rpc<T>(
        &self,
        shard: usize,
        make: impl FnOnce(SyncSender<T>) -> ShardCommand,
    ) -> Result<T, String> {
        let (rtx, rrx) = sync_channel(1);
        self.shards[shard].send(make(rtx)).map_err(|_| "shard pool down".to_string())?;
        rrx.recv().map_err(|_| "shard dropped reply".to_string())
    }

    /// Open a stream on its pinned shard. Fails if the id is in use.
    pub fn open_stream(
        &self,
        stream: &str,
        dim: usize,
        cfg: StreamConfig,
    ) -> Result<(), String> {
        self.rpc(self.shard_of(stream), |reply| ShardCommand::Open {
            stream: stream.to_string(),
            dim,
            cfg,
            reply,
        })?
    }

    /// Ingest one example into a stream (blocks under backpressure of
    /// that stream's shard only).
    pub fn ingest(&self, stream: &str, x: Vec<f64>) -> Result<IngestReply, String> {
        self.rpc(self.shard_of(stream), |reply| ShardCommand::Ingest {
            stream: stream.to_string(),
            x,
            reply,
        })?
    }

    /// Project a point onto a stream's current top-`r` components.
    pub fn project(&self, stream: &str, x: Vec<f64>, r: usize) -> Result<Vec<f64>, String> {
        self.rpc(self.shard_of(stream), |reply| ShardCommand::Project {
            stream: stream.to_string(),
            x,
            r,
            reply,
        })?
    }

    /// Force an immediate drift measurement on a stream.
    pub fn measure_drift(&self, stream: &str) -> Result<DriftPoint, String> {
        self.rpc(self.shard_of(stream), |reply| ShardCommand::MeasureDrift {
            stream: stream.to_string(),
            reply,
        })?
    }

    /// Point-in-time view of one stream.
    pub fn snapshot(&self, stream: &str) -> Result<Snapshot, String> {
        self.rpc(self.shard_of(stream), |reply| ShardCommand::Snapshot {
            stream: stream.to_string(),
            reply,
        })?
    }

    /// Per-stream metrics report.
    pub fn metrics(&self, stream: &str) -> Result<MetricsReport, String> {
        self.rpc(self.shard_of(stream), |reply| ShardCommand::Metrics {
            stream: stream.to_string(),
            reply,
        })?
    }

    /// Close a stream, freeing its state (and its kernel), returning
    /// the stream's final stats. The stream's counters stay in the
    /// shard's lifetime totals, so pool counters remain monotonic.
    pub fn close_stream(&self, stream: &str) -> Result<KpcaStats, String> {
        self.rpc(self.shard_of(stream), |reply| ShardCommand::Close {
            stream: stream.to_string(),
            reply,
        })?
    }

    /// Pool-level rollup: per-shard counters summed (including streams
    /// closed since spawn — counters are monotonic under churn), latency
    /// histograms merged, engine dispatches aggregated, per-stream
    /// gauges attached for the currently open streams.
    pub fn pool_snapshot(&self) -> Result<PoolSnapshot, String> {
        let mut snap = PoolSnapshot { shards: self.shards.len(), ..Default::default() };
        let mut ingest = LatencyHistogram::default();
        let mut project = LatencyHistogram::default();
        for shard in 0..self.shards.len() {
            let rollup = self.rpc(shard, |reply| ShardCommand::Rollup { reply })?;
            snap.streams += rollup.streams;
            snap.accepted += rollup.accepted;
            snap.excluded += rollup.excluded;
            snap.errors += rollup.errors;
            snap.total_ws_bytes += rollup.total_ws_bytes;
            snap.engine_calls.0 += rollup.engine_calls.0;
            snap.engine_calls.1 += rollup.engine_calls.1;
            ingest.merge(&rollup.ingest);
            project.merge(&rollup.project);
            snap.per_stream.extend(rollup.gauges);
        }
        snap.ingest_p50_us = ingest.percentile_ns(0.50) / 1e3;
        snap.ingest_p99_us = ingest.percentile_ns(0.99) / 1e3;
        snap.ingest_mean_us = ingest.mean_ns() / 1e3;
        snap.ingest_count = ingest.count();
        snap.project_mean_us = project.mean_ns() / 1e3;
        snap.per_stream.sort_by(|a, b| a.stream.cmp(&b.stream));
        Ok(snap)
    }
}

/// Owner of the shard worker threads. Dropping (or calling
/// [`ShardPool::shutdown`]) stops every worker and joins it; router
/// clones held elsewhere then fail cleanly with "shard pool down".
pub struct ShardPool {
    router: StreamRouter,
    joins: Vec<JoinHandle<()>>,
}

impl ShardPool {
    /// Spawn `cfg.shards` worker threads (at least one), each with its
    /// own bounded command queue and rotation engine.
    pub fn spawn(cfg: PoolConfig) -> ShardPool {
        let n = cfg.shards.max(1);
        let mut txs = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for shard in 0..n {
            let (tx, rx) = sync_channel(cfg.queue.max(1));
            let engine_cfg = cfg.engine.clone();
            joins.push(std::thread::spawn(move || shard_worker(shard, engine_cfg, rx)));
            txs.push(tx);
        }
        ShardPool { router: StreamRouter { shards: Arc::new(txs) }, joins }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.router.shards()
    }

    /// A cloneable routing handle (safe to share across producer
    /// threads).
    pub fn router(&self) -> StreamRouter {
        self.router.clone()
    }

    /// Stop all workers and join them (open streams are dropped; close
    /// streams first if their final stats matter).
    pub fn shutdown(self) {
        // Drop runs the shutdown/join sequence.
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        for tx in self.router.shards.iter() {
            let _ = tx.send(ShardCommand::Shutdown);
        }
        for join in self.joins.drain(..) {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;

    fn small_cfg() -> StreamConfig {
        StreamConfig {
            kernel: KernelConfig::Rbf { sigma: 1.0 },
            mean_adjust: true,
            seed_points: 5,
            drift_every: 0,
        }
    }

    #[test]
    fn pinning_is_deterministic_and_spreads() {
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        let mut hit = [false; 2];
        for i in 0..16 {
            let id = format!("stream-{i}");
            let s = router.shard_of(&id);
            assert_eq!(s, router.shard_of(&id), "pinning must be stable");
            assert!(s < 2);
            hit[s] = true;
        }
        assert!(hit[0] && hit[1], "16 ids should land on both shards");
        pool.shutdown();
    }

    #[test]
    fn open_twice_rejected_unknown_stream_errors() {
        let pool = ShardPool::spawn(PoolConfig::default());
        let router = pool.router();
        router.open_stream("a", 3, small_cfg()).unwrap();
        assert!(router.open_stream("a", 3, small_cfg()).is_err());
        assert!(router.ingest("nope", vec![0.0; 3]).is_err());
        assert!(router.snapshot("nope").is_err());
        assert!(router.close_stream("nope").is_err());
        pool.shutdown();
    }

    #[test]
    fn single_stream_through_pool_matches_reference() {
        let ds = yeast_like(24, 21);
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        router.open_stream("s", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest("s", ds.x.row(i).to_vec()).unwrap();
        }
        let snap = router.snapshot("s").unwrap();
        assert_eq!(snap.m, 24);
        let d = router.measure_drift("s").unwrap();
        assert!(d.norms.frobenius < 1e-7, "pool stream drift {:?}", d.norms);
        let stats = router.close_stream("s").unwrap();
        assert_eq!(stats.accepted, 24);
        pool.shutdown();
    }

    #[test]
    fn pool_snapshot_rolls_up_across_shards() {
        let ds = yeast_like(16, 22);
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        for sid in ["alpha", "beta", "gamma"] {
            router.open_stream(sid, ds.dim(), small_cfg()).unwrap();
            for i in 0..ds.n() {
                router.ingest(sid, ds.x.row(i).to_vec()).unwrap();
            }
        }
        let snap = router.pool_snapshot().unwrap();
        assert_eq!(snap.shards, 2);
        assert_eq!(snap.streams, 3);
        assert_eq!(snap.accepted, 3 * (16 - 5) as u64);
        assert_eq!(snap.ingest_count, 3 * 16);
        assert!(snap.total_ws_bytes > 0);
        assert_eq!(snap.per_stream.len(), 3);
        // Sorted by stream id, each attributed to its pinned shard.
        assert_eq!(snap.per_stream[0].stream, "alpha");
        for g in &snap.per_stream {
            assert_eq!(g.shard, router.shard_of(&g.stream));
            assert_eq!(g.m, 16);
        }
        pool.shutdown();
    }
}
