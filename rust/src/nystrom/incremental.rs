//! §4 — incremental calculation of the Nyström approximation: the
//! subset eigensystem `K_{m,m} = UΛUᵀ` is maintained by the paper's
//! incremental algorithm (rank-one updates on the shared
//! workspace/eigenbasis hot path), the cross-Gram gains one *row* per
//! added subset point — stored transposed (`m × n`) so the append is an
//! amortized `O(n)` `Vec` extend instead of a full `O(nm)` re-layout —
//! and the rescaling of eq. (7) produces the approximate eigensystem of
//! the full `K` at every step — *exactly* reproducing batch computation
//! at each `m` (paper §4), which the tests assert.

use crate::kernels::{kernel_column_into, kernel_rows_into, Kernel, KernelBlockScratch};
use crate::linalg::{matmul_nt, matmul_tn_into, transpose_into, Mat, Norms};
use crate::rankone::Rotate;

use crate::kpca::{EvictionPolicy, IncrementalKpca};

/// Incrementally grown Nyström approximation over a fixed evaluation
/// set of `n` points.
pub struct IncrementalNystrom<'k> {
    kernel: &'k dyn Kernel,
    /// All `n` data points the approximation is evaluated over.
    x: Mat,
    /// Incremental eigendecomposition of the (unadjusted) subset Gram.
    pub inc: IncrementalKpca<'k>,
    /// `m × n` *transposed* cross-Gram `K_{m,n}`: row `c` holds
    /// `k(x_{s_c}, x_j)` for all `j` — appended per subset point.
    pub kmn: Mat,
    /// Indices (into `x`) of the current subset, in insertion order.
    pub subset: Vec<usize>,
    /// Relative eigenvalue cutoff for the pseudo-inverse in eq. (7).
    pub rcond: f64,
    /// Reusable kernel-column buffer for the append.
    col_buf: Vec<f64>,
    /// Reusable flat gather of a batch's subset points (`b × dim`).
    batch_buf: Vec<f64>,
    /// Reusable `b × n` kernel-row block for the batched append.
    rows_buf: Vec<f64>,
    /// Row-norm scratch for the blocked kernel evaluation.
    kb: KernelBlockScratch,
    /// Bounded-memory cap on the subset (0 = unbounded). The bound is
    /// managed at *this* layer, not on the inner eigensystem — an inner
    /// eviction would silently desync `kmn`/`subset` from the subset
    /// Gram, so the inner bound stays off and
    /// [`IncrementalNystrom::remove_landmark`] removes all three views
    /// together.
    max_landmarks: usize,
    eviction: EvictionPolicy,
    protected: usize,
    /// Reusable leverage-score buffer for victim selection.
    lev_buf: Vec<f64>,
}

impl<'k> IncrementalNystrom<'k> {
    /// Start with an empty subset over evaluation points `x`.
    pub fn new(kernel: &'k dyn Kernel, x: Mat) -> Result<Self, String> {
        let dim = x.cols();
        let empty = Mat::zeros(0, dim);
        let inc = IncrementalKpca::from_batch(kernel, &empty, false)?;
        let n = x.rows();
        Ok(IncrementalNystrom {
            kernel,
            kmn: Mat::zeros(0, n),
            x,
            inc,
            subset: Vec::new(),
            rcond: 1e-12,
            col_buf: Vec::new(),
            batch_buf: Vec::new(),
            rows_buf: Vec::new(),
            kb: KernelBlockScratch::new(),
            max_landmarks: 0,
            eviction: EvictionPolicy::Off,
            protected: 0,
            lev_buf: Vec::new(),
        })
    }

    /// Cap the subset at `max_landmarks` points (0 = unbounded),
    /// evicting by `policy` and never evicting the first `protected`
    /// subset entries. Enforced after every accepted add (batched adds
    /// enforce once the whole batch has been absorbed).
    pub fn set_bound(&mut self, max_landmarks: usize, policy: EvictionPolicy, protected: usize) {
        self.max_landmarks = max_landmarks;
        self.eviction = policy;
        self.protected = protected;
    }

    /// Landmarks evicted so far.
    pub fn evictions(&self) -> usize {
        self.inc.evictions()
    }

    /// Sufficiency signal of the current subset — the share of the
    /// retained spectrum in its smallest positive eigenvalue (see
    /// [`IncrementalKpca::sufficiency_gap`]; the `n/m` Nyström rescale
    /// cancels, so the subset eigensystem's gauge is the
    /// approximation's too).
    pub fn sufficiency_gap(&self) -> f64 {
        self.inc.sufficiency_gap()
    }

    /// Evict subset position `c` (not an evaluation index): down-dates
    /// the subset eigensystem ([`IncrementalKpca::remove_point`]),
    /// drops the `K_{m,n}` row and the subset entry — the three views
    /// stay in lockstep.
    pub fn remove_landmark(&mut self, c: usize) -> Result<(), String> {
        self.remove_landmark_with(c, &crate::rankone::NativeRotate)
    }

    /// [`IncrementalNystrom::remove_landmark`] with an explicit rotate
    /// engine.
    pub fn remove_landmark_with(&mut self, c: usize, engine: &dyn Rotate) -> Result<(), String> {
        assert!(c < self.m(), "landmark position out of range");
        self.inc.remove_point(c, engine)?;
        self.kmn.remove_row(c);
        self.subset.remove(c);
        Ok(())
    }

    /// One bound-enforcement step (see [`IncrementalNystrom::set_bound`]).
    ///
    /// Leverage rescoring follows the same batched cadence as the KPCA
    /// layer ([`crate::kpca::LEV_REFRESH_EVERY`]): the full `O(m²)`
    /// score vector refreshes every k-th eviction; between refreshes
    /// the cache sheds victims in lockstep with `kmn`/`subset` and only
    /// the newly added landmark's row score is computed.
    fn enforce_bound_step(&mut self, engine: &dyn Rotate) -> Result<Option<usize>, String> {
        if self.max_landmarks == 0
            || self.eviction == EvictionPolicy::Off
            || self.m() <= self.max_landmarks
            || self.m() <= self.protected
        {
            return Ok(None);
        }
        let free = self.m() - self.protected;
        let c = match self.eviction {
            EvictionPolicy::Off => unreachable!("checked above"),
            EvictionPolicy::Uniform => self.protected + self.inc.evictions() % free,
            EvictionPolicy::LeverageScore => {
                let mut lev = std::mem::take(&mut self.lev_buf);
                if self.inc.evictions() % crate::kpca::LEV_REFRESH_EVERY == 0
                    || lev.len() + 1 != self.m()
                {
                    self.inc.leverage_scores(engine, &mut lev);
                } else {
                    lev.push(self.inc.leverage_score_row(self.m() - 1));
                }
                let mut c = self.protected;
                for i in self.protected + 1..self.m() {
                    if lev[i] < lev[c] {
                        c = i;
                    }
                }
                lev.remove(c);
                self.lev_buf = lev;
                c
            }
        };
        self.remove_landmark_with(c, engine)?;
        Ok(Some(c))
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Current subset size `m`.
    pub fn m(&self) -> usize {
        self.subset.len()
    }

    /// The `n × m` cross-Gram `K_{n,m}` (transposed copy — evaluation
    /// paths only; the stream maintains the `m × n` layout).
    pub fn knm(&self) -> Mat {
        let mut out = Mat::zeros(self.kmn.cols(), self.kmn.rows());
        let mut v = out.view_mut();
        transpose_into(self.kmn.view(), &mut v);
        out
    }

    /// Add evaluation point `idx` to the subset (with the native rotate
    /// engine).
    pub fn add_point(&mut self, idx: usize) -> Result<bool, String> {
        self.add_point_with(idx, &crate::rankone::NativeRotate)
    }

    /// Add evaluation point `idx` to the subset, routing the rank-one
    /// back-rotations through `engine`. Returns `Ok(false)` if the point
    /// was rejected as rank-degenerate.
    pub fn add_point_with(&mut self, idx: usize, engine: &dyn Rotate) -> Result<bool, String> {
        assert!(idx < self.n(), "subset index out of range");
        let xi = self.x.row(idx).to_vec();
        if !self.inc.push_with(&xi, engine)? {
            return Ok(false);
        }
        // Append the K_{m,n} row k(x_idx, x_j) for all j — amortized
        // O(n), no re-layout of the existing cross-Gram.
        let n = self.n();
        let mut col = std::mem::take(&mut self.col_buf);
        kernel_column_into(self.kernel, self.x.as_slice(), self.x.cols(), n, &xi, &mut col);
        self.kmn.push_row(&col);
        self.col_buf = col;
        self.subset.push(idx);
        while self.enforce_bound_step(engine)?.is_some() {}
        Ok(true)
    }

    /// Add a whole batch of evaluation points to the subset with the
    /// native rotate engine (see
    /// [`IncrementalNystrom::add_points_with`]).
    pub fn add_points(&mut self, idxs: &[usize]) -> Result<usize, String> {
        self.add_points_with(idxs, &crate::rankone::NativeRotate)
    }

    /// Pre-size the append path for subsets up to `m` points added in
    /// batches of up to `b`: the subset eigensystem's hot-path and
    /// batch scratch ([`IncrementalKpca::reserve`]) plus this layer's
    /// gather and kernel-row buffers. Warm batched adds then touch the
    /// allocator only for the amortized `kmn`/`subset` appends.
    pub fn reserve(&mut self, m: usize, b: usize) {
        self.inc.reserve(m, b);
        let n = self.n();
        let dim = self.x.cols();
        if self.batch_buf.capacity() < b * dim {
            self.batch_buf.reserve(b * dim - self.batch_buf.len());
        }
        if self.rows_buf.capacity() < b * n {
            self.rows_buf.reserve(b * n - self.rows_buf.len());
        }
        if self.col_buf.capacity() < n {
            self.col_buf.reserve(n - self.col_buf.len());
        }
        self.kb.reserve(n, b, dim);
    }

    /// Add `idxs.len()` evaluation points to the subset in one call:
    /// the subset eigensystem grows through the blocked batch entry
    /// point ([`IncrementalKpca::push_batch_with`] — the batch's kernel
    /// rows against the retained subset are one GEMM, and the batch's
    /// rank-one back-rotations fold into one fused engine GEMM under
    /// the default [`crate::kpca::BatchRotation`] auto-selection; set
    /// `self.inc.batch_rotation` to override), and the `K_{m,n}` rows
    /// of every *accepted* point are computed as one `b × n` blocked
    /// kernel-row evaluation and appended in order. Returns the number
    /// of accepted (non-degenerate) points.
    pub fn add_points_with(
        &mut self,
        idxs: &[usize],
        engine: &dyn Rotate,
    ) -> Result<usize, String> {
        let n = self.n();
        let dim = self.x.cols();
        // Gather the batch rows flat (the eigensystem and the blocked
        // kernel evaluation both want `b × dim` row-major).
        let mut ys = std::mem::take(&mut self.batch_buf);
        ys.clear();
        for &idx in idxs {
            assert!(idx < n, "subset index out of range");
            ys.extend_from_slice(self.x.row(idx));
        }
        let result = self.inc.push_batch_with(&ys, engine);
        self.batch_buf = ys;
        // Sync the subset list and cross-Gram with whatever prefix the
        // eigensystem actually accepted — on `Err` the accepted prefix
        // remains applied (the mask covers exactly the processed
        // points), and `subset`/`kmn` must not fall out of step with it.
        let b = self.inc.last_batch_mask().iter().filter(|&&ok| ok).count();
        if b > 0 {
            // One blocked kernel-row evaluation for all accepted points
            // against the full evaluation set, then amortized appends.
            let mut acc = std::mem::take(&mut self.batch_buf);
            acc.clear();
            for (&idx, &ok) in idxs.iter().zip(self.inc.last_batch_mask()) {
                if ok {
                    acc.extend_from_slice(self.x.row(idx));
                    self.subset.push(idx);
                }
            }
            let mut rows = std::mem::take(&mut self.rows_buf);
            kernel_rows_into(
                self.kernel,
                self.x.as_slice(),
                dim,
                n,
                &acc,
                b,
                &mut rows,
                &mut self.kb,
            );
            for r in 0..b {
                self.kmn.push_row(&rows[r * n..(r + 1) * n]);
            }
            self.rows_buf = rows;
            self.batch_buf = acc;
        }
        // Enforce the bound once the cross-Gram rows are in lockstep
        // with the eigensystem (the inner bound stays off, so mid-batch
        // the subset may exceed the cap by up to the batch size; it
        // converges here before the call returns).
        if result.is_ok() {
            while self.enforce_bound_step(engine)?.is_some() {}
        }
        result.map(|outcome| outcome.accepted)
    }

    /// Approximate eigenpairs of the full `K` per eq. (7).
    pub fn approx_eigs(&self) -> (Vec<f64>, Mat) {
        let n = self.n();
        let m = self.m();
        let (nf, mf) = (n as f64, m as f64);
        let lam_max = self.inc.vals.iter().fold(0.0_f64, |a, &b| a.max(b.abs()));
        let cutoff = self.rcond * lam_max;
        let vals: Vec<f64> = self.inc.vals.iter().map(|l| l * nf / mf).collect();
        let mut ulinv = self.inc.vecs.to_mat();
        for j in 0..m {
            let l = self.inc.vals[j];
            let inv = if l.abs() > cutoff { 1.0 / l } else { 0.0 };
            for i in 0..m {
                ulinv[(i, j)] *= inv;
            }
        }
        // u = K_{n,m} · UΛ⁻¹ = (K_{m,n})ᵀ · UΛ⁻¹.
        let mut u = Mat::zeros(n, m);
        {
            let mut uv = u.view_mut();
            matmul_tn_into(self.kmn.view(), ulinv.view(), &mut uv);
        }
        u.scale((mf / nf).sqrt());
        (vals, u)
    }

    /// The current approximation `K̃`.
    pub fn approx_gram(&self) -> Mat {
        let (vals, u) = self.approx_eigs();
        let (n, m) = (u.rows(), u.cols());
        let mut ul = u.clone();
        for i in 0..n {
            for j in 0..m {
                ul[(i, j)] *= vals[j];
            }
        }
        matmul_nt(&ul, &u)
    }

    /// Error norms `‖K − K̃‖` against a precomputed full Gram matrix —
    /// the Fig. 2 measurement at the current `m`.
    pub fn error_norms(&self, k_full: &Mat) -> Norms {
        crate::linalg::sym_norms(&k_full.sub(&self.approx_gram()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{magic_like, yeast_like};
    use crate::kernels::{gram, Rbf};
    use crate::nystrom::BatchNystrom;

    #[test]
    fn incremental_equals_batch_at_every_m() {
        // The §4 guarantee: the incremental Nyström approximation
        // *exactly* reproduces the batch one at each subset size.
        let ds = yeast_like(25, 1);
        let kern = Rbf { sigma: 1.0 };
        let mut inys = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        for m in 0..10 {
            assert!(inys.add_point(m).unwrap());
            let batch =
                BatchNystrom::fit(&kern, &ds.x, &(0..=m).collect::<Vec<_>>()).unwrap();
            let diff = inys.approx_gram().max_abs_diff(&batch.approx_gram());
            assert!(diff < 1e-7, "m={m}: diff {diff}");
        }
    }

    #[test]
    fn batched_add_points_matches_sequential() {
        let ds = yeast_like(20, 11);
        let kern = Rbf { sigma: 1.0 };
        let mut seq = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        for m in 0..9 {
            seq.add_point(m).unwrap();
        }
        let mut bat = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        assert_eq!(bat.add_points(&[0, 1, 2, 3]).unwrap(), 4);
        assert_eq!(bat.add_points(&[4, 5, 6, 7, 8]).unwrap(), 5);
        assert_eq!(bat.m(), 9);
        assert_eq!(bat.subset, seq.subset);
        assert!(bat.knm().max_abs_diff(&seq.knm()) < 1e-12);
        // Eigensystem rounding (the batched side applies the fused
        // rank-b rotation) passes through the rcond-clipped Λ⁻¹ of
        // eq. (7), which amplifies noise along the Gram's near-null
        // directions — compare at the suite's Nyström tolerance rather
        // than the raw-eigensystem one.
        let diff = bat.approx_gram().max_abs_diff(&seq.approx_gram());
        assert!(diff < 1e-7, "batched vs sequential Nyström diff {diff}");
        // The raw eigensystems themselves agree tightly.
        for (a, b) in bat.inc.vals.iter().zip(&seq.inc.vals) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
    }

    #[test]
    fn batched_add_points_skips_degenerate_points() {
        // Under the linear kernel a zero row has k(x,x) = 0 — the §5.1
        // exclusion fires mid-batch; its K_{m,n} row must NOT be
        // appended and the survivors must match the batch reference.
        let mut x = yeast_like(14, 12).x;
        for j in 0..x.cols() {
            x[(1, j)] = 0.0;
        }
        let kern = crate::kernels::Linear;
        let mut inys = IncrementalNystrom::new(&kern, x.clone()).unwrap();
        let accepted = inys.add_points(&[0, 1, 2, 3]).unwrap();
        assert_eq!(accepted, 3, "zero point must be excluded");
        assert_eq!(inys.m(), 3);
        assert_eq!(inys.subset, vec![0, 2, 3]);
        assert_eq!(inys.kmn.rows(), 3);
        let batch = BatchNystrom::fit(&kern, &x, &[0, 2, 3]).unwrap();
        let diff = inys.approx_gram().max_abs_diff(&batch.approx_gram());
        assert!(diff < 1e-7, "diff {diff}");
    }

    #[test]
    fn transposed_cross_gram_matches_batch_layout() {
        let ds = yeast_like(12, 7);
        let kern = Rbf { sigma: 1.0 };
        let mut inys = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        for m in 0..4 {
            inys.add_point(m).unwrap();
        }
        let batch = BatchNystrom::fit(&kern, &ds.x, &[0, 1, 2, 3]).unwrap();
        assert_eq!(inys.kmn.rows(), 4);
        assert_eq!(inys.kmn.cols(), 12);
        assert!(inys.knm().max_abs_diff(&batch.knm) < 1e-12);
    }

    #[test]
    fn error_decreases_and_full_subset_is_exact() {
        let ds = magic_like(20, 2);
        let mut std = ds.clone();
        std.standardize();
        let kern = Rbf { sigma: crate::kernels::median_heuristic(&std.x, 50) };
        let k_full = gram(&kern, &std.x);
        let mut inys = IncrementalNystrom::new(&kern, std.x.clone()).unwrap();
        let mut prev = f64::INFINITY;
        for m in 0..20 {
            inys.add_point(m).unwrap();
            let e = crate::linalg::frobenius(&k_full.sub(&inys.approx_gram()));
            if m == 4 || m == 12 {
                assert!(e <= prev + 1e-9, "error rose at m={m}");
                prev = e;
            }
        }
        let e_final = crate::linalg::frobenius(&k_full.sub(&inys.approx_gram()));
        assert!(e_final < 1e-6, "full subset error {e_final}");
    }

    #[test]
    fn approx_eigs_shapes_and_scaling() {
        let ds = yeast_like(15, 3);
        let kern = Rbf { sigma: 1.0 };
        let mut inys = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        for m in 0..5 {
            inys.add_point(m).unwrap();
        }
        let (vals, u) = inys.approx_eigs();
        assert_eq!(vals.len(), 5);
        assert_eq!(u.rows(), 15);
        assert_eq!(u.cols(), 5);
        // Eigenvalue scaling: Λⁿʸˢ = (n/m) Λ.
        for (nys, lam) in vals.iter().zip(inys.inc.vals.iter()) {
            assert!((nys - lam * 15.0 / 5.0).abs() < 1e-12);
        }
    }

    #[test]
    fn remove_landmark_matches_fresh_subset() {
        // Evicting a landmark must leave exactly the approximation a
        // batch fit over the surviving subset would compute.
        let ds = yeast_like(18, 21);
        let kern = Rbf { sigma: 1.0 };
        let mut inys = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        for m in 0..8 {
            assert!(inys.add_point(m).unwrap());
        }
        inys.remove_landmark(3).unwrap();
        assert_eq!(inys.m(), 7);
        assert_eq!(inys.subset, vec![0, 1, 2, 4, 5, 6, 7]);
        assert_eq!(inys.kmn.rows(), 7);
        let batch = BatchNystrom::fit(&kern, &ds.x, &inys.subset).unwrap();
        let diff = inys.approx_gram().max_abs_diff(&batch.approx_gram());
        assert!(diff < 1e-7, "evicted vs fresh subset diff {diff}");
    }

    #[test]
    fn bounded_subset_holds_cap_and_stays_consistent() {
        let ds = yeast_like(24, 22);
        let kern = Rbf { sigma: 1.0 };
        let mut inys = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        inys.set_bound(6, crate::kpca::EvictionPolicy::Uniform, 2);
        for m in 0..14 {
            inys.add_point(m).unwrap();
        }
        assert_eq!(inys.m(), 6, "cap must hold");
        assert_eq!(inys.evictions(), 14 - 6);
        assert_eq!(inys.kmn.rows(), 6);
        assert_eq!(inys.subset.len(), 6);
        // The protected prefix survives every eviction.
        assert_eq!(&inys.subset[..2], &[0, 1]);
        assert!(inys.sufficiency_gap() >= 0.0);
        // All three views agree with a fresh batch fit of the survivors.
        let batch = BatchNystrom::fit(&kern, &ds.x, &inys.subset).unwrap();
        let diff = inys.approx_gram().max_abs_diff(&batch.approx_gram());
        assert!(diff < 1e-6, "bounded subset vs fresh fit diff {diff}");
    }

    #[test]
    fn bounded_batched_adds_converge_to_cap() {
        let ds = yeast_like(20, 23);
        let kern = Rbf { sigma: 1.2 };
        let mut inys = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        inys.set_bound(5, crate::kpca::EvictionPolicy::LeverageScore, 0);
        inys.add_points(&[0, 1, 2, 3]).unwrap();
        inys.add_points(&[4, 5, 6, 7, 8, 9]).unwrap();
        assert_eq!(inys.m(), 5);
        assert_eq!(inys.kmn.rows(), 5);
        let batch = BatchNystrom::fit(&kern, &ds.x, &inys.subset).unwrap();
        let diff = inys.approx_gram().max_abs_diff(&batch.approx_gram());
        assert!(diff < 1e-6, "diff {diff}");
    }

    /// Enough leverage evictions to straddle several full-rescore
    /// refresh points (`LEV_REFRESH_EVERY`): the cached-score fast path
    /// keeps `kmn`/`subset`/eigensystem in lockstep and the bounded
    /// subset still reproduces a fresh batch fit of the survivors.
    #[test]
    fn leverage_cache_cadence_keeps_views_lockstep() {
        let ds = yeast_like(40, 24);
        let kern = Rbf { sigma: 1.2 };
        let mut inys = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        inys.set_bound(8, crate::kpca::EvictionPolicy::LeverageScore, 3);
        for m in 0..ds.n() {
            inys.add_point(m).unwrap();
            assert_eq!(inys.kmn.rows(), inys.subset.len(), "views desynced at {m}");
            assert_eq!(inys.inc.len(), inys.subset.len(), "eigensystem desynced at {m}");
        }
        assert_eq!(inys.m(), 8);
        assert!(
            inys.evictions() > 3 * crate::kpca::LEV_REFRESH_EVERY,
            "run too short to exercise the cadence: {} evictions",
            inys.evictions()
        );
        assert_eq!(&inys.subset[..3], &[0, 1, 2], "protected prefix evicted");
        let batch = BatchNystrom::fit(&kern, &ds.x, &inys.subset).unwrap();
        let diff = inys.approx_gram().max_abs_diff(&batch.approx_gram());
        assert!(diff < 1e-6, "bounded subset vs fresh fit diff {diff}");
    }

    #[test]
    fn error_norms_bundle_consistent() {
        let ds = yeast_like(12, 4);
        let kern = Rbf { sigma: 1.0 };
        let k_full = gram(&kern, &ds.x);
        let mut inys = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        for m in 0..4 {
            inys.add_point(m).unwrap();
        }
        let norms = inys.error_norms(&k_full);
        assert!(norms.spectral <= norms.frobenius + 1e-9);
        assert!(norms.frobenius <= norms.trace + 1e-9);
    }
}
