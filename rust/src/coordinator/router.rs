//! Engine router: picks, per rank-one update, whether the `2m³`
//! back-rotation runs on the native blocked GEMM or the AOT PJRT
//! executable (bucket-laddered Pallas kernel). Policy: PJRT above a
//! size threshold when a runtime is attached, native otherwise — small
//! problems lose more to padding/transfer than the kernel gains.
//!
//! In the shard pool one `RoutedEngine` exists *per shard worker* and
//! is shared by every stream pinned to that shard (the engine is
//! stateless apart from its atomic dispatch counters, which the pool
//! snapshot sums across shards). Batched ingest (`ingest_many`) drives
//! the same engine: the `b` rank-one update sequences of a batch
//! dispatch through it back to back, so the policy threshold applies
//! per update exactly as in the rendezvous path.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::linalg::{MatView, MatViewMut};
use crate::rankone::{NativeRotate, Rotate};
use crate::runtime::PjrtRotate;
use crate::secular::SecularRoot;

/// Which engine to use.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum EnginePolicy {
    /// Always the native GEMM.
    #[default]
    Native,
    /// Always PJRT (falls back to native only on artifact miss).
    Pjrt,
    /// PJRT for problems of at least this order, native below.
    Auto {
        pjrt_min: usize,
    },
}

/// Routing engine with dispatch counters (surfaced in metrics).
pub struct RoutedEngine {
    native: NativeRotate,
    pjrt: Option<PjrtRotate>,
    pub policy: EnginePolicy,
    pub native_calls: AtomicU64,
    pub pjrt_calls: AtomicU64,
}

impl RoutedEngine {
    pub fn native_only() -> Self {
        RoutedEngine {
            native: NativeRotate,
            pjrt: None,
            policy: EnginePolicy::Native,
            native_calls: AtomicU64::new(0),
            pjrt_calls: AtomicU64::new(0),
        }
    }

    pub fn with_pjrt(pjrt: PjrtRotate, policy: EnginePolicy) -> Self {
        RoutedEngine {
            native: NativeRotate,
            pjrt: Some(pjrt),
            policy,
            native_calls: AtomicU64::new(0),
            pjrt_calls: AtomicU64::new(0),
        }
    }

    fn use_pjrt(&self, size: usize) -> bool {
        if self.pjrt.is_none() {
            return false;
        }
        match self.policy {
            EnginePolicy::Native => false,
            EnginePolicy::Pjrt => true,
            EnginePolicy::Auto { pjrt_min } => size >= pjrt_min,
        }
    }

    /// (native, pjrt) dispatch counts.
    pub fn counts(&self) -> (u64, u64) {
        (self.native_calls.load(Ordering::Relaxed), self.pjrt_calls.load(Ordering::Relaxed))
    }
}

impl Rotate for RoutedEngine {
    fn rotate_into(&self, u: MatView<'_>, w: MatView<'_>, out: MatViewMut<'_>) {
        self.native_calls.fetch_add(1, Ordering::Relaxed);
        self.native.rotate_into(u, w, out);
    }

    fn rotate_fused_into(
        &self,
        u: MatView<'_>,
        z: &[f64],
        d: &[f64],
        roots: &[SecularRoot],
        out: MatViewMut<'_>,
    ) -> bool {
        let size = u.rows().max(u.cols());
        if self.use_pjrt(size) {
            if let Some(p) = &self.pjrt {
                if p.rotate_fused_into(u, z, d, roots, out) {
                    self.pjrt_calls.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
            }
        }
        false // fall through to the native W-form rotate_into()
    }

    fn name(&self) -> &'static str {
        "routed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::Rbf;
    use crate::kpca::IncrementalKpca;

    #[test]
    fn native_only_routes_everything_native() {
        let engine = RoutedEngine::native_only();
        let ds = yeast_like(10, 1);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 4..10 {
            inc.push_with(ds.x.row(i), &engine).unwrap();
        }
        let (native, pjrt) = engine.counts();
        assert!(native > 0);
        assert_eq!(pjrt, 0);
    }

    #[test]
    fn auto_policy_thresholds() {
        // Without a pjrt runtime attached, Auto always declines.
        let engine = RoutedEngine::native_only();
        assert!(!engine.use_pjrt(10_000));
        let e2 = RoutedEngine {
            policy: EnginePolicy::Auto { pjrt_min: 64 },
            ..RoutedEngine::native_only()
        };
        assert!(!e2.use_pjrt(1024)); // still no pjrt runtime
    }

    #[test]
    fn pjrt_policy_with_runtime_if_artifacts_present() {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.tsv").exists() {
            return;
        }
        let rt = std::sync::Arc::new(crate::runtime::Runtime::new(dir).unwrap());
        let engine = RoutedEngine::with_pjrt(
            crate::runtime::PjrtRotate::new(rt),
            EnginePolicy::Pjrt,
        );
        let ds = yeast_like(10, 2);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 4..10 {
            inc.push_with(ds.x.row(i), &engine).unwrap();
        }
        let (_, pjrt) = engine.counts();
        assert!(pjrt > 0, "pjrt engine never dispatched");
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-6);
    }
}
