"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes, dtypes and seeds — the CORE correctness signal
for the compute layer the rust runtime executes.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import eigvec, rbf
from compile.kernels.ref import (
    eigvec_update_ref,
    eigvec_weights_ref,
    rbf_column_ref,
    rbf_gram_ref,
)

DTYPES = [np.float32, np.float64]


def rng_arrays(seed, m, d, dtype):
    r = np.random.RandomState(seed)
    x = r.randn(m, d).astype(dtype)
    y = r.randn(d).astype(dtype)
    return x, y


@settings(deadline=None, max_examples=24)
@given(
    seed=st.integers(0, 2**31 - 1),
    mblocks=st.integers(1, 4),
    d=st.integers(1, 24),
    dtype=st.sampled_from(DTYPES),
    block=st.sampled_from([8, 32, 128]),
)
def test_rbf_column_matches_ref(seed, mblocks, d, dtype, block):
    m = mblocks * block
    x, y = rng_arrays(seed, m, d, dtype)
    sigma = 1.7
    got = rbf.rbf_column(x, y, sigma, block_m=block)
    want = rbf_column_ref(jnp.asarray(x), jnp.asarray(y), sigma)
    tol = 1e-6 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@settings(deadline=None, max_examples=12)
@given(
    seed=st.integers(0, 2**31 - 1),
    nblocks=st.integers(1, 3),
    d=st.integers(1, 16),
    dtype=st.sampled_from(DTYPES),
    block=st.sampled_from([8, 32]),
)
def test_rbf_gram_matches_ref(seed, nblocks, d, dtype, block):
    n = nblocks * block
    x, _ = rng_arrays(seed, n, d, dtype)
    sigma = 2.3
    got = rbf.rbf_gram(x, sigma, block=block)
    want = rbf_gram_ref(jnp.asarray(x), sigma)
    tol = 2e-5 if dtype == np.float32 else 1e-11
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_rbf_gram_symmetric_unit_diagonal():
    x, _ = rng_arrays(3, 64, 5, np.float64)
    g = np.asarray(rbf.rbf_gram(x, 1.0, block=32))
    np.testing.assert_allclose(g, g.T, atol=1e-14)
    np.testing.assert_allclose(np.diag(g), 1.0, atol=1e-14)


def _interlaced_problem(seed, k, dtype):
    """Random poles + roots satisfying strict interlacing (the regime the
    kernel is used in: secular roots always sit between poles)."""
    r = np.random.RandomState(seed)
    lam = np.sort(r.rand(k) * 4.0).astype(dtype)
    gaps = np.diff(lam, append=lam[-1] + 1.0)
    lam_new = (lam + 0.5 * gaps).astype(dtype)
    z = (r.randn(k) * 0.7).astype(dtype)
    z[np.abs(z) < 1e-3] = 1e-3  # keep well-conditioned
    return lam, lam_new, z


@settings(deadline=None, max_examples=20)
@given(
    seed=st.integers(0, 2**31 - 1),
    mblocks=st.integers(1, 3),
    kblocks=st.integers(1, 3),
    dtype=st.sampled_from(DTYPES),
    block=st.sampled_from([8, 16]),
)
def test_eigvec_rotate_matches_ref(seed, mblocks, kblocks, dtype, block):
    m = mblocks * block
    k = kblocks * block
    r = np.random.RandomState(seed)
    u = r.randn(m, k).astype(dtype)
    lam, lam_new, z = _interlaced_problem(seed + 1, k, dtype)
    w = eigvec_weights_ref(jnp.asarray(z), jnp.asarray(lam), jnp.asarray(lam_new))
    inv = 1.0 / jnp.maximum(jnp.sqrt(jnp.sum(w * w, axis=0)), 1e-300)
    got = eigvec.rotate(u, z, lam, lam_new, np.asarray(inv, dtype), bm=block, bn=block, bk=block)
    want = eigvec_update_ref(
        jnp.asarray(u), jnp.asarray(z), jnp.asarray(lam), jnp.asarray(lam_new)
    )
    tol = 5e-4 if dtype == np.float32 else 1e-10
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_eigvec_rotate_multiblock_accumulation():
    """K-loop accumulation across > 1 grid step must agree with one-shot."""
    m, k = 32, 32
    r = np.random.RandomState(0)
    u = r.randn(m, k)
    lam, lam_new, z = _interlaced_problem(5, k, np.float64)
    w = eigvec_weights_ref(jnp.asarray(z), jnp.asarray(lam), jnp.asarray(lam_new))
    inv = np.asarray(1.0 / jnp.sqrt(jnp.sum(w * w, axis=0)))
    one = eigvec.rotate(u, z, lam, lam_new, inv, bm=32, bn=32, bk=32)
    split = eigvec.rotate(u, z, lam, lam_new, inv, bm=16, bn=16, bk=8)
    np.testing.assert_allclose(one, split, rtol=1e-12, atol=1e-12)


def test_eigvec_padding_contract():
    """Zero-padded rows/columns behave per the runtime::pad contract."""
    m, k, pad = 16, 16, 16
    r = np.random.RandomState(1)
    u = r.randn(m, k)
    lam, lam_new, z = _interlaced_problem(2, k, np.float64)
    # Padded problem: U zero rows/cols, z zeros, sentinel eigenvalues far
    # from the real spectrum.
    up = np.zeros((m + pad, k + pad))
    up[:m, :k] = u
    zp = np.concatenate([z, np.zeros(pad)])
    sent = 1e12 + np.arange(pad)
    lamp = np.concatenate([lam, sent])
    lamnp = np.concatenate([lam_new, sent + 0.5])
    wp = eigvec_weights_ref(jnp.asarray(zp), jnp.asarray(lamp), jnp.asarray(lamnp))
    invp = np.asarray(1.0 / jnp.maximum(jnp.sqrt(jnp.sum(wp * wp, axis=0)), 1e-300))
    got = eigvec.rotate(up, zp, lamp, lamnp, invp, bm=16, bn=16, bk=16)
    want = eigvec_update_ref(
        jnp.asarray(u), jnp.asarray(z), jnp.asarray(lam), jnp.asarray(lam_new)
    )
    np.testing.assert_allclose(got[:m, :k], want, rtol=1e-10, atol=1e-10)
    # Padded output rows are exactly zero (zero rows of U).
    np.testing.assert_allclose(got[m:, :], 0.0, atol=1e-300)


def test_rotate_orthogonality_on_real_update():
    """End-to-end eq. 6 sanity: rotating the eigenvectors of a random
    symmetric A by the true secular roots of A + sigma v v^T yields an
    orthonormal basis."""
    k = 24
    r = np.random.RandomState(7)
    a = r.randn(k, k)
    a = 0.5 * (a + a.T)
    lam, u = np.linalg.eigh(a)
    v = r.randn(k)
    sigma = 0.9
    b = a + sigma * np.outer(v, v)
    lam_new = np.linalg.eigvalsh(b)
    z = u.T @ v
    got = np.asarray(
        eigvec.rotate(
            u,
            z,
            lam,
            lam_new,
            np.asarray(
                1.0
                / np.sqrt(
                    np.sum(
                        np.square(z[:, None] / (lam[:, None] - lam_new[None, :])), axis=0
                    )
                )
            ),
            bm=8,
            bn=8,
            bk=8,
        )
    )
    np.testing.assert_allclose(got.T @ got, np.eye(k), atol=1e-7)
    np.testing.assert_allclose(got @ np.diag(lam_new) @ got.T, b, atol=1e-7)
