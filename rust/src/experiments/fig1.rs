//! Figure 1 — drift of the incremental eigendecomposition: the three
//! norms of `K'_{m} − U_m Λ_m U_mᵀ` as the eigensystem grows from
//! `m₀ = 20`, on both datasets, for one run and the mean of `runs`
//! shuffled-order runs (§5.1). Also records the `‖UUᵀ − I‖_F`
//! orthogonality diagnostic (S1) and the excluded-example count.

use std::io::Write;

use crate::data::{load, Dataset};
use crate::kernels::{median_heuristic, Rbf};
use crate::kpca::IncrementalKpca;
use crate::linalg::{orthogonality_defect, sym_norms, Norms};
use crate::util::{par, Rng};

use super::RunMode;

#[derive(Clone, Debug)]
pub struct Fig1Config {
    pub datasets: Vec<String>,
    /// Seed batch size (paper: 20).
    pub m0: usize,
    /// Final eigensystem size.
    pub n_max: usize,
    /// Shuffled repetitions for the mean curve (paper: 50).
    pub runs: usize,
    /// Measure drift every this many accepted points.
    pub measure_every: usize,
    /// Mean-adjusted (Algorithm 2) vs unadjusted (Algorithm 1).
    pub mean_adjust: bool,
    pub seed: u64,
}

impl Fig1Config {
    pub fn new(mode: RunMode) -> Self {
        match mode {
            RunMode::Quick => Fig1Config {
                datasets: vec!["magic".into(), "yeast".into()],
                m0: 20,
                n_max: 120,
                runs: 5,
                measure_every: 5,
                mean_adjust: true,
                seed: 42,
            },
            // Paper scale is m → full dataset with per-step measurement;
            // on this single-core image we grow to 220 and sample every
            // 10 steps — the drift-vs-m *shape* is unchanged (EXPERIMENTS.md).
            RunMode::Full => Fig1Config {
                datasets: vec!["magic".into(), "yeast".into()],
                m0: 20,
                n_max: 220,
                runs: 50,
                measure_every: 10,
                mean_adjust: true,
                seed: 42,
            },
        }
    }
}

/// One measured point on a drift curve.
#[derive(Clone, Copy, Debug)]
pub struct DriftSample {
    pub m: usize,
    pub norms: Norms,
    pub orthogonality: f64,
}

/// Drift curve for one (dataset, run).
pub fn drift_curve(
    ds: &Dataset,
    cfg: &Fig1Config,
    order: &[usize],
) -> Result<(Vec<DriftSample>, usize), String> {
    let shuffled = ds.permuted(order);
    let sigma = median_heuristic(&shuffled.x, 200);
    let kern = Rbf { sigma };
    let seed = shuffled.x.submatrix(cfg.m0, shuffled.dim());
    let mut inc = IncrementalKpca::from_batch(&kern, &seed, cfg.mean_adjust)?;
    let mut samples = Vec::new();
    let end = cfg.n_max.min(shuffled.n());
    for i in cfg.m0..end {
        inc.push(shuffled.x.row(i))?;
        let step = i + 1 - cfg.m0;
        if step % cfg.measure_every == 0 || i + 1 == end {
            let diff = inc.reconstruct().sub(&inc.batch_reference());
            samples.push(DriftSample {
                m: inc.len(),
                norms: sym_norms(&diff),
                orthogonality: orthogonality_defect(&inc.vecs),
            });
        }
    }
    Ok((samples, inc.stats.excluded))
}

/// Run the full Figure-1 harness; returns (dataset, mean-curve) pairs.
pub fn run_fig1(cfg: &Fig1Config) -> Result<Vec<(String, Vec<DriftSample>)>, String> {
    let (mut csv, path) = super::csv_writer(
        "fig1_drift.csv",
        "dataset,adjusted,run,m,frobenius,spectral,trace,orthogonality",
    )
    .map_err(|e| e.to_string())?;
    let mut out = Vec::new();
    for name in &cfg.datasets {
        let ds = load(name, cfg.n_max + cfg.m0, cfg.seed)?;
        let mut std_ds = ds.clone();
        std_ds.standardize();
        // Run 0 is the in-order single run; runs 1.. are shuffled.
        let orders: Vec<Vec<usize>> = (0..=cfg.runs)
            .map(|r| {
                if r == 0 {
                    (0..std_ds.n()).collect()
                } else {
                    Rng::new(cfg.seed ^ (r as u64) << 16).permutation(std_ds.n())
                }
            })
            .collect();
        let curves: Vec<Result<(Vec<DriftSample>, usize), String>> =
            par::par_map(orders.len(), 1, |r| drift_curve(&std_ds, cfg, &orders[r]));
        let mut all = Vec::new();
        for (r, c) in curves.into_iter().enumerate() {
            let (samples, excluded) = c?;
            if excluded > 0 {
                println!("fig1 {name} run {r}: {excluded} examples excluded (§5.1)");
            }
            for s in &samples {
                writeln!(
                    csv,
                    "{name},{},{r},{},{:.6e},{:.6e},{:.6e},{:.6e}",
                    cfg.mean_adjust, s.m, s.norms.frobenius, s.norms.spectral, s.norms.trace,
                    s.orthogonality
                )
                .map_err(|e| e.to_string())?;
            }
            all.push(samples);
        }
        // Mean over the shuffled runs (1..), matching the paper's plot.
        let mean = mean_curve(&all[1..]);
        print_summary(name, &all[0], &mean);
        out.push((name.clone(), mean));
    }
    println!("fig1: wrote {}", path.display());
    Ok(out)
}

fn mean_curve(runs: &[Vec<DriftSample>]) -> Vec<DriftSample> {
    if runs.is_empty() || runs[0].is_empty() {
        return Vec::new();
    }
    let npts = runs.iter().map(|r| r.len()).min().unwrap();
    (0..npts)
        .map(|i| {
            let k = runs.len() as f64;
            DriftSample {
                m: runs[0][i].m,
                norms: Norms {
                    frobenius: runs.iter().map(|r| r[i].norms.frobenius).sum::<f64>() / k,
                    spectral: runs.iter().map(|r| r[i].norms.spectral).sum::<f64>() / k,
                    trace: runs.iter().map(|r| r[i].norms.trace).sum::<f64>() / k,
                },
                orthogonality: runs.iter().map(|r| r[i].orthogonality).sum::<f64>() / k,
            }
        })
        .collect()
}

fn print_summary(name: &str, single: &[DriftSample], mean: &[DriftSample]) {
    println!("── Fig. 1 drift: {name} ──");
    println!("{:>6} {:>12} {:>12} {:>12} {:>12}", "m", "frobenius", "spectral", "trace", "‖UUᵀ−I‖");
    for s in mean {
        println!(
            "{:>6} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e}",
            s.m, s.norms.frobenius, s.norms.spectral, s.norms.trace, s.orthogonality
        );
    }
    if let (Some(f), Some(l)) = (single.first(), single.last()) {
        println!(
            "single run: frobenius {:.3e} @ m={} → {:.3e} @ m={}",
            f.norms.frobenius, f.m, l.norms.frobenius, l.m
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_fig1_runs_and_drift_small() {
        let cfg = Fig1Config {
            datasets: vec!["yeast".into()],
            m0: 8,
            n_max: 24,
            runs: 2,
            measure_every: 4,
            mean_adjust: true,
            seed: 7,
        };
        let out = run_fig1(&cfg).unwrap();
        assert_eq!(out.len(), 1);
        let (_, mean) = &out[0];
        assert!(!mean.is_empty());
        // Exact algorithm at small scale: drift ≈ machine precision.
        for s in mean {
            assert!(s.norms.frobenius < 1e-7, "drift {:?}", s.norms);
        }
        // ms increase.
        for w in mean.windows(2) {
            assert!(w[0].m < w[1].m);
        }
    }

    #[test]
    fn unadjusted_variant_runs() {
        let cfg = Fig1Config {
            datasets: vec!["magic".into()],
            m0: 6,
            n_max: 18,
            runs: 1,
            measure_every: 3,
            mean_adjust: false,
            seed: 3,
        };
        let out = run_fig1(&cfg).unwrap();
        assert_eq!(out[0].1.len(), 4);
    }
}
