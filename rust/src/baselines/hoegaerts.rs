//! Hoegaerts et al. (2007): "Efficiently updating and tracking the
//! dominant kernel principal components" — maintains only the top `r`
//! eigenpairs of the *unadjusted* kernel matrix under expansion,
//! updating via two rank-one perturbations and truncating back to the
//! dominant subspace each step (§2.3 of the paper).
//!
//! The update is exact while `r = m` and becomes a dominant-subspace
//! approximation once truncation starts: the component of each
//! perturbation orthogonal to the tracked subspace is discarded —
//! exactly the trade their tracker makes. Shares the
//! workspace/eigenbasis storage for its rank-one updates (in-place
//! expansion and truncation); per-step vectors still allocate — it is a
//! comparison baseline, not the production hot path.

use crate::kernels::{kernel_column_into, Kernel};
use crate::linalg::Mat;
use crate::rankone::{
    rank_one_update_ws, sort_pairs_ws, EigenBasis, NativeRotate, Rotate, UpdateWorkspace,
};

/// Dominant-subspace tracker for the unadjusted kernel matrix.
#[derive(Clone)]
pub struct HoegaertsTracker<'k> {
    kernel: &'k dyn Kernel,
    x: Vec<f64>,
    dim: usize,
    m: usize,
    /// Number of dominant eigenpairs tracked.
    pub r: usize,
    /// Tracked eigenvalues, ascending (length ≤ r).
    pub vals: Vec<f64>,
    /// Tracked eigenvectors (`m × len(vals)`).
    pub vecs: EigenBasis,
    /// Per-stream rank-one scratch.
    ws: UpdateWorkspace,
}

impl<'k> HoegaertsTracker<'k> {
    /// Initialize from a batch decomposition of `x0`, keeping the top
    /// `r` eigenpairs.
    pub fn from_batch(kernel: &'k dyn Kernel, x0: &Mat, r: usize) -> Result<Self, String> {
        let m = x0.rows();
        if m == 0 || r == 0 {
            return Err("hoegaerts needs ≥1 seed point and r ≥ 1".into());
        }
        let k = crate::kernels::gram(kernel, x0);
        let eg = crate::linalg::eigh(&k)?;
        let keep = r.min(m);
        let first = m - keep;
        let mut vecs = Mat::zeros(m, keep);
        let mut vals = Vec::with_capacity(keep);
        for (c, j) in (first..m).enumerate() {
            vals.push(eg.values[j]);
            for i in 0..m {
                vecs[(i, c)] = eg.vectors[(i, j)];
            }
        }
        Ok(HoegaertsTracker {
            kernel,
            x: x0.as_slice().to_vec(),
            dim: x0.cols(),
            m,
            r,
            vals,
            vecs: EigenBasis::from_mat(vecs),
            ws: UpdateWorkspace::new(),
        })
    }

    pub fn len(&self) -> usize {
        self.m
    }

    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    /// Ingest one example: expand, two rank-one updates, truncate.
    pub fn push(&mut self, xnew: &[f64]) -> Result<(), String> {
        self.push_with(xnew, &NativeRotate)
    }

    pub fn push_with(&mut self, xnew: &[f64], engine: &dyn Rotate) -> Result<(), String> {
        assert_eq!(xnew.len(), self.dim);
        let m = self.m;
        // Kernel column over the flat retained data — no matrix clone.
        let mut a = Vec::with_capacity(m);
        kernel_column_into(self.kernel, &self.x, self.dim, m, xnew, &mut a);
        let knew = self.kernel.eval(xnew, xnew);
        if knew.abs() < 1e-14 {
            return Err("degenerate self-similarity".into());
        }

        // Expand the tracked (rectangular) system with the decoupled
        // eigenpair (k/4, e_{m+1}) — in place on the capacity-slack
        // storage.
        let (rows, cols) = (self.vecs.rows(), self.vecs.cols());
        self.vecs.expand();
        self.vecs[(rows, cols)] = 1.0;
        self.vals.push(0.25 * knew);
        sort_pairs_ws(&mut self.vals, &mut self.vecs, &mut self.ws);

        // Two rank-one updates (eq. 2), projected onto the tracked
        // subspace by the rectangular eigenvector matrix.
        let sigma = 4.0 / knew;
        let mut v1 = a.clone();
        v1.push(0.5 * knew);
        let mut v2 = a;
        v2.push(0.25 * knew);
        rank_one_update_ws(&mut self.vals, &mut self.vecs, sigma, &v1, engine, &mut self.ws)?;
        rank_one_update_ws(&mut self.vals, &mut self.vecs, -sigma, &v2, engine, &mut self.ws)?;

        // Truncate back to the r dominant pairs (largest are at the
        // end); an in-place column shift.
        while self.vals.len() > self.r {
            self.vals.remove(0);
            self.vecs.remove_col(0);
        }

        self.x.extend_from_slice(xnew);
        self.m += 1;
        Ok(())
    }

    /// Low-rank reconstruction `U_r Λ_r U_rᵀ`.
    pub fn reconstruct(&self) -> Mat {
        let (m, c) = (self.vecs.rows(), self.vecs.cols());
        let mut ul = self.vecs.to_mat();
        for i in 0..m {
            for j in 0..c {
                ul[(i, j)] *= self.vals[j];
            }
        }
        crate::linalg::matmul_nt(&ul, &self.vecs)
    }

    /// Best rank-r batch approximation of the current kernel matrix —
    /// the quality target for the tracker.
    pub fn batch_rank_r(&self) -> Result<Mat, String> {
        let xmat = Mat::from_vec(self.m, self.dim, self.x.clone());
        let k = crate::kernels::gram(self.kernel, &xmat);
        let eg = crate::linalg::eigh(&k)?;
        let keep = self.r.min(self.m);
        let first = self.m - keep;
        let mut ul = Mat::zeros(self.m, keep);
        for (c, j) in (first..self.m).enumerate() {
            for i in 0..self.m {
                ul[(i, c)] = eg.vectors[(i, j)] * eg.values[j];
            }
        }
        let mut u = Mat::zeros(self.m, keep);
        for (c, j) in (first..self.m).enumerate() {
            for i in 0..self.m {
                u[(i, c)] = eg.vectors[(i, j)];
            }
        }
        Ok(crate::linalg::matmul_nt(&ul, &u))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::Rbf;
    use crate::linalg::frobenius;

    #[test]
    fn exact_while_untruncated() {
        // With r ≥ m the tracker is the exact unadjusted incremental
        // algorithm.
        let ds = yeast_like(12, 1);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut tr = HoegaertsTracker::from_batch(&kern, &seed, 64).unwrap();
        for i in 4..ds.n() {
            tr.push(ds.x.row(i)).unwrap();
        }
        let k = crate::kernels::gram(&kern, &ds.x);
        assert!(tr.reconstruct().max_abs_diff(&k) < 1e-8);
    }

    #[test]
    fn truncated_tracks_dominant_subspace() {
        let ds = yeast_like(30, 2);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(10, ds.dim());
        let r = 6;
        let mut tr = HoegaertsTracker::from_batch(&kern, &seed, r).unwrap();
        for i in 10..ds.n() {
            tr.push(ds.x.row(i)).unwrap();
        }
        // Tracker error should be within a modest factor of the optimal
        // rank-r error (it cannot beat it).
        let k = crate::kernels::gram(&kern, &ds.x);
        let best = tr.batch_rank_r().unwrap();
        let e_best = frobenius(&k.sub(&best));
        let e_tr = frobenius(&k.sub(&tr.reconstruct()));
        assert!(e_tr >= e_best - 1e-9, "tracker cannot beat optimal");
        assert!(e_tr < 6.0 * e_best + 1e-6, "tracker off: {e_tr} vs optimal {e_best}");
    }

    #[test]
    fn rank_capped_at_r() {
        let ds = yeast_like(15, 3);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(5, ds.dim());
        let mut tr = HoegaertsTracker::from_batch(&kern, &seed, 4).unwrap();
        for i in 5..ds.n() {
            tr.push(ds.x.row(i)).unwrap();
            assert!(tr.vals.len() <= 4);
            assert_eq!(tr.vecs.rows(), tr.len());
        }
    }
}
