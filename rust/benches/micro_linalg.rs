//! Micro-benchmarks of the substrate hot paths: blocked GEMM, the
//! symmetric eigensolver, the secular root finder and the rank-one
//! update in both forms — the allocating compatibility path vs the
//! zero-allocation workspace path — at sizes up to m=512. Emits
//! `BENCH_rankone.json` plus `BENCH_micro_linalg.json` (packed vs
//! unpacked GEMM at the hot-path shapes) so the perf trajectory is
//! recorded run-over-run.

use inkpca::linalg::{
    eigh, matmul, matmul_into_buf, matmul_into_unpacked, matmul_nt_into_buf,
    matmul_nt_into_unpacked, Mat, PackBuffers,
};
use inkpca::rankone::{
    rank_one_update, rank_one_update_ws, EigenBasis, NativeRotate, UpdateWorkspace,
};
use inkpca::secular::solve_all;
use inkpca::util::bench::Bench;
use inkpca::util::Rng;

fn rand_rect(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.range(-1.0, 1.0))
}

fn rand_mat(n: usize, seed: u64) -> Mat {
    rand_rect(n, n, seed)
}

fn rand_sym(n: usize, seed: u64) -> Mat {
    let mut m = rand_mat(n, seed);
    m.symmetrize();
    m
}

fn main() {
    let mut b = Bench::new();
    for n in [128usize, 256, 512] {
        let a = rand_mat(n, 1);
        let c = rand_mat(n, 2);
        b.case(&format!("linalg/gemm/n{n}"), || matmul(&a, &c).max_abs());
    }
    for n in [64usize, 128, 256] {
        let s = rand_sym(n, 3);
        b.case(&format!("linalg/eigh/n{n}"), || eigh(&s).unwrap().values[0]);
    }
    for n in [64usize, 256, 1024] {
        let mut rng = Rng::new(4);
        let mut d: Vec<f64> = (0..n).map(|_| rng.range(0.0, 10.0)).collect();
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let z: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        b.case(&format!("secular/solve_all/n{n}"), || {
            solve_all(&d, &z, 1.5).unwrap().len()
        });
    }

    // Rank-one update: allocating compatibility path vs warmed workspace
    // path, on an *evolving* eigensystem (alternating ±σ keeps the
    // spectrum bounded) so the steady-state allocation behaviour — not a
    // per-sample clone — is what gets measured. The workspace rows must
    // come out measurably faster at m ≥ 512 (acceptance criterion).
    for n in [128usize, 256, 512] {
        let s = rand_sym(n, 5);
        let eg = eigh(&s).unwrap();

        let mut vals_a = eg.values.clone();
        let mut vecs_a = eg.vectors.clone();
        let mut rng_a = Rng::new(6);
        let mut v_a = vec![0.0; n];
        let mut flip_a = false;
        b.case(&format!("rankone/update_alloc/n{n}"), || {
            for x in v_a.iter_mut() {
                *x = rng_a.range(-1.0, 1.0);
            }
            flip_a = !flip_a;
            let sigma = if flip_a { 1.0 } else { -1.0 };
            rank_one_update(&mut vals_a, &mut vecs_a, sigma, &v_a, &NativeRotate)
                .unwrap()
                .solved
        });

        let mut vals_w = eg.values.clone();
        let mut basis = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws = UpdateWorkspace::new();
        ws.reserve(n, n);
        let mut rng_w = Rng::new(6);
        let mut v_w = vec![0.0; n];
        let mut flip_w = false;
        b.case(&format!("rankone/update_ws/n{n}"), || {
            for x in v_w.iter_mut() {
                *x = rng_w.range(-1.0, 1.0);
            }
            flip_w = !flip_w;
            let sigma = if flip_w { 1.0 } else { -1.0 };
            rank_one_update_ws(&mut vals_w, &mut basis, sigma, &v_w, &NativeRotate, &mut ws)
                .unwrap()
                .solved
        });
        assert_eq!(ws.reallocs(), 0, "warmed workspace must stay allocation-free");
    }

    // Expansion: the per-accepted-example grow step, measured on a
    // growing system (each sample adds one eigenpair, as a stream
    // does). The allocating path re-layouts the full matrix per call;
    // the workspace path grows in place — amortized O(1) reallocation,
    // O(m) writes.
    for n in [128usize, 256, 512] {
        let vals0: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let eye = Mat::eye(n);
        let mut vals_a = vals0.clone();
        let mut vecs_a = eye.clone();
        b.case(&format!("rankone/expand_alloc/n{n}"), || {
            let new_val = vals_a.last().unwrap() + 1.0;
            inkpca::rankone::expand_eigensystem(&mut vals_a, &mut vecs_a, new_val);
            vals_a.len()
        });
        let mut vals_w = vals0.clone();
        let mut basis = EigenBasis::from_mat(eye.clone());
        let mut ws = UpdateWorkspace::new();
        b.case(&format!("rankone/expand_ws/n{n}"), || {
            let new_val = vals_w.last().unwrap() + 1.0;
            inkpca::rankone::expand_eigensystem_ws(&mut vals_w, &mut basis, new_val, &mut ws);
            vals_w.len()
        });
    }

    b.finish();
    if let Err(e) = b.write_json("BENCH_rankone.json") {
        eprintln!("warning: could not write BENCH_rankone.json: {e}");
    } else {
        println!("wrote BENCH_rankone.json");
    }

    // Packed vs unpacked GEMM at the three hot-path product shapes: the
    // blocked-flush back-rotation (m×r · r×r), the snapshot projection
    // (b×m · m×r, b = one read batch), and kernel-block rows
    // (b×dim · (m×dim)ᵀ via the NT variant). Acceptance: packed ≥1.5×
    // unpacked at m ≥ 512; the series lands in BENCH_micro_linalg.json
    // under the bench_compare gate.
    let mut ml = Bench::new();
    for m in [128usize, 512, 2048] {
        let r = m.min(256);
        let batch = 64usize;
        let dim = 64usize;
        let mut bufs = PackBuffers::new();
        bufs.reserve(m, r, r);
        bufs.reserve(batch, m, r);
        bufs.reserve(batch, dim, m);

        let a = rand_rect(m, r, 11);
        let w = rand_rect(r, r, 12);
        let mut c = Mat::zeros(m, r);
        let pk_f = ml.case(&format!("gemm_flush/packed/m{m}"), || {
            let mut cv = c.view_mut();
            matmul_into_buf(a.view(), w.view(), &mut cv, &mut bufs);
            c[(0, 0)]
        });
        let un_f = ml.case(&format!("gemm_flush/unpacked/m{m}"), || {
            let mut cv = c.view_mut();
            matmul_into_unpacked(a.view(), w.view(), &mut cv);
            c[(0, 0)]
        });
        println!("  flush m={m}: packed speedup {:.2}x", un_f.median_ns / pk_f.median_ns);

        let blk = rand_rect(batch, m, 13);
        let basis = rand_rect(m, r, 14);
        let mut proj = Mat::zeros(batch, r);
        let pk_p = ml.case(&format!("gemm_project/packed/m{m}"), || {
            let mut pv = proj.view_mut();
            matmul_into_buf(blk.view(), basis.view(), &mut pv, &mut bufs);
            proj[(0, 0)]
        });
        let un_p = ml.case(&format!("gemm_project/unpacked/m{m}"), || {
            let mut pv = proj.view_mut();
            matmul_into_unpacked(blk.view(), basis.view(), &mut pv);
            proj[(0, 0)]
        });
        println!("  project m={m}: packed speedup {:.2}x", un_p.median_ns / pk_p.median_ns);

        let yb = rand_rect(batch, dim, 15);
        let xs = rand_rect(m, dim, 16);
        let mut krows = Mat::zeros(batch, m);
        let pk_k = ml.case(&format!("gemm_krows/packed/m{m}"), || {
            let mut kv = krows.view_mut();
            matmul_nt_into_buf(yb.view(), xs.view(), &mut kv, &mut bufs);
            krows[(0, 0)]
        });
        let un_k = ml.case(&format!("gemm_krows/unpacked/m{m}"), || {
            let mut kv = krows.view_mut();
            matmul_nt_into_unpacked(yb.view(), xs.view(), &mut kv);
            krows[(0, 0)]
        });
        println!("  krows m={m}: packed speedup {:.2}x", un_k.median_ns / pk_k.median_ns);

        assert_eq!(bufs.reallocs(), 0, "reserved pack buffers must stay allocation-free");
    }
    ml.finish();
    if let Err(e) = ml.write_json("BENCH_micro_linalg.json") {
        eprintln!("warning: could not write BENCH_micro_linalg.json: {e}");
    } else {
        println!("wrote BENCH_micro_linalg.json");
    }
}
