//! Batch kernel PCA (§2.2): form the (optionally mean-adjusted) Gram
//! matrix and eigendecompose it — the `O(n³)`-per-call baseline the
//! incremental algorithm is measured against, and the ground truth for
//! the drift experiments (Fig. 1).

use crate::kernels::{gram, Kernel};
use crate::linalg::{eigh, Mat};

use super::centering::center_gram;

/// A fitted batch kernel PCA model.
#[derive(Clone, Debug)]
pub struct BatchKpca {
    /// Eigenvalues of the (adjusted) kernel matrix, ascending.
    pub values: Vec<f64>,
    /// Matching eigenvectors (columns).
    pub vectors: Mat,
    /// The uncentered Gram matrix.
    pub k: Mat,
    /// The matrix that was decomposed (equals `k` when not adjusting).
    pub k_used: Mat,
    /// Whether the mean adjustment (eq. 1) was applied.
    pub mean_adjusted: bool,
}

impl BatchKpca {
    /// Fit on the rows of `x`.
    pub fn fit(kernel: &dyn Kernel, x: &Mat, mean_adjust: bool) -> Result<Self, String> {
        let k = gram(kernel, x);
        Self::fit_gram(k, mean_adjust)
    }

    /// Fit from a precomputed (uncentered) Gram matrix.
    pub fn fit_gram(k: Mat, mean_adjust: bool) -> Result<Self, String> {
        let k_used = if mean_adjust { center_gram(&k) } else { k.clone() };
        let eg = eigh(&k_used)?;
        Ok(BatchKpca { values: eg.values, vectors: eg.vectors, k, k_used, mean_adjusted: mean_adjust })
    }

    /// The top `r` eigenvalues, descending (principal components order).
    pub fn top_values(&self, r: usize) -> Vec<f64> {
        self.values.iter().rev().take(r).copied().collect()
    }

    /// Reconstruction `U Λ Uᵀ` of the decomposed matrix.
    pub fn reconstruct(&self) -> Mat {
        let n = self.values.len();
        let mut vl = self.vectors.clone();
        for i in 0..n {
            for j in 0..n {
                vl[(i, j)] *= self.values[j];
            }
        }
        crate::linalg::matmul_nt(&vl, &self.vectors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::Rbf;

    #[test]
    fn reconstruction_matches_gram() {
        let ds = yeast_like(25, 1);
        let model = BatchKpca::fit(&Rbf { sigma: 1.0 }, &ds.x, false).unwrap();
        assert!(model.reconstruct().max_abs_diff(&model.k) < 1e-9);
    }

    #[test]
    fn adjusted_reconstruction_matches_centered_gram() {
        let ds = yeast_like(20, 2);
        let model = BatchKpca::fit(&Rbf { sigma: 1.0 }, &ds.x, true).unwrap();
        assert!(model.reconstruct().max_abs_diff(&model.k_used) < 1e-9);
        // Centered Gram has a (near-)zero eigenvalue (constant vector in
        // its kernel).
        assert!(model.values[0].abs() < 1e-9);
    }

    #[test]
    fn top_values_descending() {
        let ds = yeast_like(15, 3);
        let model = BatchKpca::fit(&Rbf { sigma: 0.5 }, &ds.x, true).unwrap();
        let top = model.top_values(5);
        for w in top.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }
}
