//! Datasets and streaming sources. The paper evaluates on the UCI
//! *Magic gamma telescope* and *Yeast* datasets (§5); those files are
//! not available in this offline environment, so `synthetic` provides
//! statistically faithful generators (documented in DESIGN.md §3), and
//! `csv` loads the real files when they are dropped into `data/`.

pub mod csv;
pub mod stream;
pub mod synthetic;

pub use stream::{SliceSource, StreamSource};
pub use synthetic::{magic_like, yeast_like};

use crate::linalg::Mat;

/// A named dataset: dense rows of features.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Mat,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// The leading `n` rows as a new dataset (paper §5.2 uses the first
    /// 1000 observations).
    pub fn head(&self, n: usize) -> Dataset {
        Dataset { name: self.name.clone(), x: self.x.submatrix(n.min(self.n()), self.dim()) }
    }

    /// Rows permuted by `perm` (used for the 50-run averages in §5).
    pub fn permuted(&self, perm: &[usize]) -> Dataset {
        assert_eq!(perm.len(), self.n());
        let x = Mat::from_fn(self.n(), self.dim(), |i, j| self.x[(perm[i], j)]);
        Dataset { name: self.name.clone(), x }
    }

    /// Standardize each column to zero mean / unit variance (in place).
    pub fn standardize(&mut self) {
        let (n, d) = (self.n(), self.dim());
        if n == 0 {
            return;
        }
        for j in 0..d {
            let mean: f64 = (0..n).map(|i| self.x[(i, j)]).sum::<f64>() / n as f64;
            let var: f64 =
                (0..n).map(|i| (self.x[(i, j)] - mean).powi(2)).sum::<f64>() / n as f64;
            let sd = var.sqrt().max(1e-12);
            for i in 0..n {
                self.x[(i, j)] = (self.x[(i, j)] - mean) / sd;
            }
        }
    }
}

/// Resolve a dataset by name: `magic` / `yeast` load the real UCI CSV
/// from `data/` when present and otherwise fall back to the synthetic
/// generator with the given size and seed.
pub fn load(name: &str, n: usize, seed: u64) -> Result<Dataset, String> {
    match name {
        "magic" => {
            if let Ok(ds) = csv::load_csv("data/magic04.data", "magic", Some(10)) {
                Ok(ds.head(n))
            } else {
                Ok(magic_like(n, seed))
            }
        }
        "yeast" => {
            if let Ok(ds) = csv::load_csv("data/yeast.data", "yeast", Some(8)) {
                Ok(ds.head(n))
            } else {
                Ok(yeast_like(n, seed))
            }
        }
        other => Err(format!("unknown dataset '{other}' (expected magic|yeast)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_and_permuted() {
        let ds = magic_like(20, 1);
        let h = ds.head(5);
        assert_eq!(h.n(), 5);
        assert_eq!(h.x.row(3), ds.x.row(3));
        let perm: Vec<usize> = (0..20).rev().collect();
        let p = ds.permuted(&perm);
        assert_eq!(p.x.row(0), ds.x.row(19));
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut ds = magic_like(200, 2);
        ds.standardize();
        for j in 0..ds.dim() {
            let mean: f64 = (0..ds.n()).map(|i| ds.x[(i, j)]).sum::<f64>() / ds.n() as f64;
            let var: f64 =
                (0..ds.n()).map(|i| ds.x[(i, j)].powi(2)).sum::<f64>() / ds.n() as f64;
            assert!(mean.abs() < 1e-10);
            assert!((var - 1.0).abs() < 1e-8);
        }
    }

    #[test]
    fn load_falls_back_to_synthetic() {
        let ds = load("magic", 50, 3).unwrap();
        assert_eq!(ds.n(), 50);
        assert_eq!(ds.dim(), 10);
        assert!(load("nope", 10, 0).is_err());
    }
}
