//! The secular equation solver at the heart of the paper's §3.2:
//! eigenvalues of `Λ + σ z zᵀ` are the roots of
//!
//! ```text
//! ω(λ̃) = 1 + σ Σᵢ zᵢ² / (λᵢ − λ̃)            (paper eq. 4, Golub 1973)
//! ```
//!
//! bracketed by the interlacing bounds of eq. (5). Each root is found by
//! a safeguarded Newton iteration in a *pole-relative* coordinate
//! `δ = λ̃ − λ_origin`, which preserves relative accuracy when the root
//! sits very close to a pole (the same device LAPACK's `dlaed4` uses).
//!
//! The blocked rank-b batch path solves its `b` secular systems against
//! the *evolving* spectrum one after another (each solve needs only the
//! previous roots, never the rotated eigenvectors), gated by the
//! `O(n)` non-mutating [`deflate::is_clean`] probe — a system that
//! would deflate falls back to the sequential update instead of being
//! folded into the pending rotation product (see `rankone`).

pub mod deflate;

pub use deflate::{deflate, deflate_into, is_clean, Deflation};

/// One root of the secular equation, kept in pole-relative form so that
/// downstream difference computations `λⱼ − λ̃ᵢ` can be formed without
/// cancellation.
#[derive(Clone, Copy, Debug)]
pub struct SecularRoot {
    /// Index of the pole `λ_origin` the root is expressed against.
    pub origin: usize,
    /// Offset from that pole; the root is `d[origin] + delta`.
    pub delta: f64,
    /// The root value itself (`d[origin] + delta`, precomputed).
    pub value: f64,
}

impl SecularRoot {
    /// Difference `d[j] − root`, formed in pole-relative coordinates.
    #[inline]
    pub fn diff(&self, d: &[f64], j: usize) -> f64 {
        (d[j] - d[self.origin]) - self.delta
    }
}

/// Evaluate `ω` and `ω'` at `origin + delta`, pole-relatively.
fn eval(d: &[f64], z: &[f64], sigma: f64, origin: usize, delta: f64) -> (f64, f64) {
    let mut s = 0.0;
    let mut sp = 0.0;
    for j in 0..d.len() {
        let denom = (d[j] - d[origin]) - delta;
        let t = z[j] / denom;
        s += z[j] * t; // z²/denom
        sp += t * t; // z²/denom²
    }
    (1.0 + sigma * s, sigma * sp)
}

/// Maximum Newton/bisection iterations per root.
const MAX_ITER: usize = 120;

/// Solve for the root of `ω` lying in `(origin + lo, origin + hi)` in
/// pole-relative coordinates, where `ω` changes sign across the bracket.
fn solve_in(
    d: &[f64],
    z: &[f64],
    sigma: f64,
    origin: usize,
    mut lo: f64,
    mut hi: f64,
) -> Result<f64, String> {
    debug_assert!(lo < hi);
    // Nudge brackets strictly inside: ω is ±∞ at the poles themselves.
    let width = hi - lo;
    let tiny = width * 1e-15;
    lo += tiny;
    hi -= tiny;
    let mut x = 0.5 * (lo + hi);
    for _ in 0..MAX_ITER {
        let (f, fp) = eval(d, z, sigma, origin, x);
        if !f.is_finite() {
            // Landed on a pole — bisect.
            x = 0.5 * (lo + hi);
            continue;
        }
        // Maintain the bracket. ω is monotone increasing iff σ > 0.
        if (f > 0.0) == (sigma > 0.0) {
            hi = x;
        } else {
            lo = x;
        }
        // Convergence: function tiny relative to its terms, or bracket
        // exhausted at f64 resolution.
        let scale: f64 = 1.0
            + sigma.abs()
                * z.iter()
                    .zip(d)
                    .map(|(zj, dj)| {
                        let denom = (dj - d[origin]) - x;
                        (zj * zj / denom).abs()
                    })
                    .sum::<f64>();
        if f.abs() <= 8.0 * f64::EPSILON * scale {
            return Ok(x);
        }
        if hi - lo <= 4.0 * f64::EPSILON * (x.abs().max(d[origin].abs()).max(1e-300)) {
            return Ok(0.5 * (lo + hi));
        }
        // Newton step, safeguarded into the bracket.
        let step = f / fp;
        let mut next = x - step;
        if !(next > lo && next < hi) || !next.is_finite() {
            next = 0.5 * (lo + hi);
        }
        if next == x {
            return Ok(x);
        }
        x = next;
    }
    Ok(x) // best effort after MAX_ITER — still inside the bracket
}

/// Solve the full secular equation for sorted poles `d` (ascending) and
/// weights `z`, perturbation strength `sigma != 0`. Returns one root per
/// pole, sorted ascending, each in pole-relative form.
///
/// Callers should deflate tiny `z` entries first (see [`deflate`]); a
/// zero weight makes its interval degenerate (handled by returning the
/// pole itself).
pub fn solve_all(d: &[f64], z: &[f64], sigma: f64) -> Result<Vec<SecularRoot>, String> {
    let mut roots = Vec::new();
    let mut reallocs = 0u64;
    solve_all_into(d, z, sigma, &mut roots, &mut reallocs)?;
    Ok(roots)
}

/// [`solve_all`] into a caller-owned, capacity-retaining buffer — the
/// zero-allocation form used by `rankone::UpdateWorkspace`. `reallocs`
/// is bumped when `roots` had to grow (zero once warm).
pub fn solve_all_into(
    d: &[f64],
    z: &[f64],
    sigma: f64,
    roots: &mut Vec<SecularRoot>,
    reallocs: &mut u64,
) -> Result<(), String> {
    let n = d.len();
    assert_eq!(z.len(), n);
    if roots.capacity() < n {
        *reallocs += 1;
        roots.reserve(n);
    }
    roots.clear();
    if n == 0 {
        return Ok(());
    }
    debug_assert!(d.windows(2).all(|w| w[0] <= w[1]), "poles must be sorted");
    let zz: f64 = z.iter().map(|x| x * x).sum();
    if zz == 0.0 || sigma == 0.0 {
        roots.extend((0..n).map(|i| SecularRoot { origin: i, delta: 0.0, value: d[i] }));
        return Ok(());
    }
    if sigma > 0.0 {
        // Roots interlace from above: root i ∈ (λᵢ, λᵢ₊₁), last in
        // (λₙ, λₙ + σ‖z‖²).                                 (eq. 5)
        for i in 0..n {
            let (origin, lo, hi);
            if i + 1 < n {
                let gap = d[i + 1] - d[i];
                if gap == 0.0 {
                    // Exactly repeated pole (caller should have deflated;
                    // be safe): the root collapses onto the pole.
                    roots.push(SecularRoot { origin: i, delta: 0.0, value: d[i] });
                    continue;
                }
                // Choose the nearer pole as origin by probing the midpoint.
                let (fmid, _) = eval(d, z, sigma, i, 0.5 * gap);
                if fmid >= 0.0 {
                    origin = i;
                    lo = 0.0;
                    hi = 0.5 * gap;
                } else {
                    origin = i + 1;
                    lo = -0.5 * gap;
                    hi = 0.0;
                }
            } else {
                origin = n - 1;
                lo = 0.0;
                hi = sigma * zz;
            }
            let delta = solve_in(d, z, sigma, origin, lo, hi)?;
            roots.push(SecularRoot { origin, delta, value: d[origin] + delta });
        }
    } else {
        // σ < 0: roots interlace from below: root i ∈ (λᵢ₋₁, λᵢ),
        // first in (λ₁ + σ‖z‖², λ₁).                        (eq. 5)
        for i in 0..n {
            let (origin, lo, hi);
            if i > 0 {
                let gap = d[i] - d[i - 1];
                if gap == 0.0 {
                    roots.push(SecularRoot { origin: i, delta: 0.0, value: d[i] });
                    continue;
                }
                let (fmid, _) = eval(d, z, sigma, i, -0.5 * gap);
                // ω decreases from +∞ at λᵢ₋₁⁺ to −∞ at λᵢ⁻: a
                // non-positive midpoint value puts the root in the left
                // half, nearer pole i−1.
                if fmid <= 0.0 {
                    origin = i - 1;
                    lo = 0.0;
                    hi = 0.5 * gap;
                } else {
                    origin = i;
                    lo = -0.5 * gap;
                    hi = 0.0;
                }
            } else {
                origin = 0;
                lo = sigma * zz; // negative
                hi = 0.0;
            }
            let delta = solve_in(d, z, sigma, origin, lo, hi)?;
            roots.push(SecularRoot { origin, delta, value: d[origin] + delta });
        }
    }
    Ok(())
}

/// Direct evaluation of `ω(x)` (test/diagnostic helper).
pub fn secular_value(d: &[f64], z: &[f64], sigma: f64, x: f64) -> f64 {
    1.0 + sigma * d.iter().zip(z).map(|(dj, zj)| zj * zj / (dj - x)).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{eigvalsh, Mat};

    fn brute_force(d: &[f64], z: &[f64], sigma: f64) -> Vec<f64> {
        let mut a = Mat::from_diag(d);
        a.syr(sigma, z);
        eigvalsh(&a).unwrap()
    }

    #[test]
    fn matches_dense_eig_positive_sigma() {
        let d = vec![0.5, 1.0, 2.0, 4.0];
        let z = vec![0.3, -0.2, 0.5, 0.1];
        let roots = solve_all(&d, &z, 1.5).unwrap();
        let expect = brute_force(&d, &z, 1.5);
        for (r, e) in roots.iter().zip(expect.iter()) {
            assert!((r.value - e).abs() < 1e-10, "{} vs {}", r.value, e);
        }
    }

    #[test]
    fn matches_dense_eig_negative_sigma() {
        let d = vec![0.5, 1.0, 2.0, 4.0];
        let z = vec![0.3, -0.2, 0.5, 0.1];
        let roots = solve_all(&d, &z, -0.8).unwrap();
        let expect = brute_force(&d, &z, -0.8);
        for (r, e) in roots.iter().zip(expect.iter()) {
            assert!((r.value - e).abs() < 1e-10, "{} vs {}", r.value, e);
        }
    }

    #[test]
    fn interlacing_bounds_hold() {
        let d = vec![-1.0, 0.0, 0.7, 1.3, 5.0];
        let z = vec![0.4, 0.1, -0.3, 0.2, 0.6];
        let zz: f64 = z.iter().map(|x| x * x).sum();
        for sigma in [2.0, -2.0] {
            let roots = solve_all(&d, &z, sigma).unwrap();
            for (i, r) in roots.iter().enumerate() {
                if sigma > 0.0 {
                    assert!(r.value >= d[i] - 1e-12);
                    let ub = if i + 1 < d.len() { d[i + 1] } else { d[i] + sigma * zz };
                    assert!(r.value <= ub + 1e-12);
                } else {
                    assert!(r.value <= d[i] + 1e-12);
                    let lb = if i > 0 { d[i - 1] } else { d[0] + sigma * zz };
                    assert!(r.value >= lb - 1e-12);
                }
            }
        }
    }

    #[test]
    fn roots_are_actual_zeros() {
        let d = vec![1.0, 2.0, 3.0];
        let z = vec![0.5, 0.5, 0.5];
        let roots = solve_all(&d, &z, 1.0).unwrap();
        for r in &roots {
            let f = secular_value(&d, &z, 1.0, r.value);
            assert!(f.abs() < 1e-8, "ω({}) = {}", r.value, f);
        }
    }

    #[test]
    fn tight_cluster_resolved() {
        // Poles separated by 1e-9 — pole-relative coordinates keep the
        // roots distinct and inside their intervals.
        let d = vec![1.0, 1.0 + 1e-9, 1.0 + 2e-9, 2.0];
        let z = vec![1e-3, 1e-3, 1e-3, 0.5];
        let roots = solve_all(&d, &z, 1.0).unwrap();
        for i in 0..3 {
            assert!(roots[i].value >= d[i] - 1e-18);
            assert!(roots[i].value <= d[i + 1] + 1e-18);
        }
        let expect = brute_force(&d, &z, 1.0);
        assert!((roots[3].value - expect[3]).abs() < 1e-9);
    }

    #[test]
    fn zero_sigma_or_zero_z_is_identity() {
        let d = vec![1.0, 2.0];
        let roots = solve_all(&d, &[0.0, 0.0], 3.0).unwrap();
        assert_eq!(roots[0].value, 1.0);
        assert_eq!(roots[1].value, 2.0);
        let roots = solve_all(&d, &[0.5, 0.5], 0.0).unwrap();
        assert_eq!(roots[1].value, 2.0);
    }

    #[test]
    fn trace_is_preserved() {
        // tr(Λ + σzzᵀ) = Σλ + σ‖z‖² must equal the sum of roots.
        let d = vec![0.1, 0.4, 0.9, 1.6, 2.5];
        let z = vec![0.2, -0.1, 0.3, 0.05, -0.25];
        let sigma = 2.3;
        let roots = solve_all(&d, &z, sigma).unwrap();
        let zz: f64 = z.iter().map(|x| x * x).sum();
        let lhs: f64 = roots.iter().map(|r| r.value).sum();
        let rhs: f64 = d.iter().sum::<f64>() + sigma * zz;
        assert!((lhs - rhs).abs() < 1e-9 * rhs.abs().max(1.0));
    }

    #[test]
    fn empty_input() {
        assert!(solve_all(&[], &[], 1.0).unwrap().is_empty());
    }

    #[test]
    fn property_random_problems_match_dense() {
        crate::util::prop::check("secular-matches-dense", 24, |rng| {
            let n = 2 + rng.below(10);
            let mut d: Vec<f64> = (0..n).map(|_| rng.range(-3.0, 3.0)).collect();
            d.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let z: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
            let sigma = if rng.uniform() < 0.5 { rng.range(0.1, 3.0) } else { rng.range(-3.0, -0.1) };
            let roots = solve_all(&d, &z, sigma).map_err(|e| e.to_string())?;
            let expect = brute_force(&d, &z, sigma);
            for (r, e) in roots.iter().zip(expect.iter()) {
                crate::util::prop::close("root", r.value, *e, 1e-8)?;
            }
            Ok(())
        });
    }
}
