//! Fig. 2 bench — incremental Nyström inner loops: adding a subset
//! point (rank-one eigen update + K_{n,m} column), the eq.-7 rescaling
//! / reconstruction, and the error-norm evaluation, vs recomputing the
//! batch Nyström from scratch at the same size (the §4 pitch: the
//! incremental path makes per-size evaluation affordable).

use inkpca::data::load;
use inkpca::kernels::{gram, median_heuristic, Rbf};
use inkpca::linalg::psd_norms;
use inkpca::nystrom::{BatchNystrom, CholeskyNystrom, IncrementalNystrom};
use inkpca::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let n = if std::env::var("INKPCA_BENCH_FAST").is_ok() { 200 } else { 400 };
    let mut ds = load("yeast", n, 42).unwrap();
    ds.standardize();
    let sigma = median_heuristic(&ds.x, 200);
    let kern = Rbf { sigma };
    let k_full = gram(&kern, &ds.x);

    for m in [32usize, 64, 96] {
        // Prepared incremental state with m subset points.
        let mut inys = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        for i in 0..m {
            inys.add_point(i).unwrap();
        }

        b.case(&format!("fig2/reconstruct/n{n}/m{m}"), || inys.approx_gram().max_abs());

        b.case(&format!("fig2/error_norms/n{n}/m{m}"), || {
            let diff = k_full.sub(&inys.approx_gram());
            psd_norms(&diff).frobenius
        });

        b.case(&format!("fig2/batch_refit/n{n}/m{m}"), || {
            let subset: Vec<usize> = (0..m).collect();
            BatchNystrom::fit(&kern, &ds.x, &subset).unwrap().values.len()
        });

        // Rudi-style Cholesky baseline reconstruction at the same size.
        let mut chol = CholeskyNystrom::new(&kern, ds.x.clone());
        for i in 0..m {
            chol.add_point(i).unwrap();
        }
        b.case(&format!("fig2/cholesky_reconstruct/n{n}/m{m}"), || {
            chol.approx_gram().max_abs()
        });
    }

    // The add-point step itself at m=64 (clone + add).
    let mut base = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
    for i in 0..64 {
        base.add_point(i).unwrap();
    }
    b.case("fig2/add_point/m64", || {
        // No Clone on IncrementalNystrom (borrows kernel); measure the
        // underlying eigen-update via the KPCA state instead.
        let mut inc = base.inc.clone();
        inc.push(ds.x.row(65)).unwrap()
    });
    b.finish();
    if let Err(e) = b.write_json("BENCH_fig2.json") {
        eprintln!("warning: could not write BENCH_fig2.json: {e}");
    } else {
        println!("wrote BENCH_fig2.json");
    }
}
