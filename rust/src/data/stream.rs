//! Streaming-source abstraction: the paper's motivating setting is data
//! arriving "sequentially in time" (§2.3). The coordinator pulls
//! examples from a [`StreamSource`]; implementations here wrap in-memory
//! datasets, optionally rate-limited to emulate a live feed.

use std::time::Duration;

use super::Dataset;

/// A (possibly unbounded) stream of feature vectors.
pub trait StreamSource: Send {
    /// Dimensionality of the emitted vectors.
    fn dim(&self) -> usize;
    /// Next example, or `None` when the stream ends.
    fn next_example(&mut self) -> Option<Vec<f64>>;
    /// Examples remaining, if known.
    fn remaining(&self) -> Option<usize>;
}

/// Streams the rows of a dataset in order.
pub struct SliceSource {
    ds: Dataset,
    pos: usize,
    /// Optional inter-arrival delay emulating a live feed.
    pub delay: Option<Duration>,
}

impl SliceSource {
    pub fn new(ds: Dataset) -> Self {
        SliceSource { ds, pos: 0, delay: None }
    }

    pub fn with_delay(ds: Dataset, delay: Duration) -> Self {
        SliceSource { ds, pos: 0, delay: Some(delay) }
    }
}

impl StreamSource for SliceSource {
    fn dim(&self) -> usize {
        self.ds.dim()
    }

    fn next_example(&mut self) -> Option<Vec<f64>> {
        if self.pos >= self.ds.n() {
            return None;
        }
        if let Some(d) = self.delay {
            std::thread::sleep(d);
        }
        let row = self.ds.x.row(self.pos).to_vec();
        self.pos += 1;
        Some(row)
    }

    fn remaining(&self) -> Option<usize> {
        Some(self.ds.n() - self.pos)
    }
}

/// Endless synthetic stream drawing fresh examples from a generator
/// closure — used by soak/property tests of the coordinator.
pub struct GeneratorSource<F: FnMut() -> Vec<f64> + Send> {
    dim: usize,
    gen: F,
}

impl<F: FnMut() -> Vec<f64> + Send> GeneratorSource<F> {
    pub fn new(dim: usize, gen: F) -> Self {
        GeneratorSource { dim, gen }
    }
}

impl<F: FnMut() -> Vec<f64> + Send> StreamSource for GeneratorSource<F> {
    fn dim(&self) -> usize {
        self.dim
    }
    fn next_example(&mut self) -> Option<Vec<f64>> {
        let v = (self.gen)();
        debug_assert_eq!(v.len(), self.dim);
        Some(v)
    }
    fn remaining(&self) -> Option<usize> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;

    #[test]
    fn slice_source_exhausts_in_order() {
        let ds = yeast_like(5, 1);
        let first = ds.x.row(0).to_vec();
        let mut src = SliceSource::new(ds);
        assert_eq!(src.remaining(), Some(5));
        assert_eq!(src.next_example().unwrap(), first);
        let mut count = 1;
        while src.next_example().is_some() {
            count += 1;
        }
        assert_eq!(count, 5);
        assert_eq!(src.remaining(), Some(0));
    }

    #[test]
    fn generator_source_never_ends() {
        let mut k = 0.0;
        let mut src = GeneratorSource::new(2, move || {
            k += 1.0;
            vec![k, -k]
        });
        assert_eq!(src.remaining(), None);
        assert_eq!(src.next_example().unwrap(), vec![1.0, -1.0]);
        assert_eq!(src.next_example().unwrap(), vec![2.0, -2.0]);
    }
}
